"""Frame-lifecycle tracing + deadline-miss attribution (observability).

The scheduler's whole argument is about *where* a frame's latency budget
goes — wire delay, reorder-buffer residency, DisBatcher window wait, EDF
queueing, device execution, overrun — yet counters alone can only say
THAT a deadline was missed. This module is the unified telemetry layer
from wire to completion:

- :class:`FrameTracer` — a low-overhead, loop-generic tracer. Components
  hold a ``tracer`` attribute (default ``None`` — the zero-cost off
  path: one identity check per hook) and stamp span events for every hop
  a frame takes: wire send/receive, reassembly delivery, gateway
  ingest/shed, admission verdicts, window close, EDF enqueue/dispatch,
  chunk fuse, device submit/complete, watchdog verdicts, health
  transitions. Events land in a FIXED-CAPACITY ring (old events evict,
  counted — a tracer left on for a week cannot leak), and the tracer
  only ever reads timestamps its caller passes from ``loop.now``, so it
  works identically under the virtual ``EventLoop`` and the live
  ``WallClock`` (the ``FaultPlan``/``LinkPlan`` convention).
- Deadline-miss attribution — per-frame stamps are folded, at the
  frame's TERMINAL span (exactly one of ``completed`` / ``late`` /
  ``shed`` / ``lost``, mirroring the conservation identity
  ``completed + dropped + lost == ingested``), into a per-stage budget
  breakdown: wire / reorder_buffer / window / queue / device / overrun.
  The stages are consecutive stamp deltas, so they sum EXACTLY to the
  frame's observed latency; late frames' breakdowns aggregate per
  category and per slice (which stage ate the slack), and each miss is
  kept in a capped log for postmortems.
- :class:`LatencyHistogram` — streaming fixed-log-bucket percentiles
  (p50/p95/p99 without storing samples): ``Metrics`` keeps these always
  and its unbounded sample lists only behind ``record_samples``, so a
  scheduler serving millions of frames holds O(1) metric memory.
- Chrome ``trace_event`` export (:meth:`FrameTracer.chrome_trace`) for
  timeline viewing in ``chrome://tracing`` / Perfetto, and a generic
  ``/metrics``-style text exposition (:func:`render_text`) over the
  cluster's JSON snapshot (``ClusterScheduler.telemetry_snapshot``).

Adding a stage: pick a constant below, ``emit`` it from the component
with ``loop.now``, and — if it should participate in attribution — stamp
it in ``_STAMP_STAGES`` so the breakdown picks it up. Stages not listed
there are annotation lanes (admission, watchdog, health) that ride the
ring for the timeline but never shift attribution.
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

# ---------------------------------------------------------------------------
# Span taxonomy
# ---------------------------------------------------------------------------

# Frame-lifecycle hops (in pipeline order).
WIRE_SEND = "wire_send"                # client put the datagram on the wire
WIRE_RECV = "wire_recv"                # server first saw the datagram
REASSEMBLY = "reassembly_deliver"      # in-order release from the reorder buffer
INGEST = "ingest"                      # deadline-stamped into the scheduler
WINDOW_CLOSE = "window_close"          # DisBatcher joint batched the frame
EDF_ENQUEUE = "edf_enqueue"            # job pushed into the deadline queue
EDF_DISPATCH = "edf_dispatch"          # job popped + started on the device
CHUNK_FUSE = "chunk_fuse"              # depth decision for a fused dispatch
DEVICE_SUBMIT = "device_submit"        # handed to the device contract
DEVICE_COMPLETE = "device_complete"    # device completion (carries dur)
DEVICE_MEASURED = "device_measured"    # live measured-vs-expected report

# Annotation lanes (never part of a frame's attribution chain).
ADMISSION = "admission"                # admission verdict for a request
WATCHDOG_OVERDUE = "watchdog_overdue"  # completion watchdog fired
HEALTH_TRANSITION = "health_transition"  # slice health state change

# Terminal spans: every delivered frame's trace ends in EXACTLY one.
COMPLETED = "completed"                # finished at or before its deadline
LATE = "late"                          # finished past its deadline (a miss)
SHED = "shed"                          # dropped at the gateway / late-rejected
LOST = "lost"                          # destroyed (wire loss / died with slice)
TERMINAL_STAGES = frozenset({COMPLETED, LATE, SHED, LOST})

# Attribution stage names, in budget order.
ATTR_STAGES = ("wire", "reorder_buffer", "window", "queue", "device", "overrun")

# emit()-stage -> stamp slot consumed by the attribution fold.
_STAMP_STAGES = {
    WIRE_RECV: "recv",
    REASSEMBLY: "deliver",
    INGEST: "ingest",
    WINDOW_CLOSE: "window_close",
    EDF_DISPATCH: "dispatch",
}


class SpanEvent(NamedTuple):
    """One structured span event in the ring."""

    t: float
    stage: str
    rid: int          # request id (-1: system-level event)
    idx: int          # frame index within the request (-1: system-level)
    where: Optional[str]   # slice name / component tag
    cat: Optional[str]     # category label
    meta: Optional[Dict]   # small free-form payload (kept JSON-able)


class FrameTracer:
    """Fixed-capacity ring of span events + miss attribution.

    One tracer instance spans the whole stack (transport, gateway, every
    slice's scheduler): components tag their events with ``where`` so a
    single ring still separates slices in the export. All methods run on
    the loop thread (the AsyncDevice/WallClock posting convention keeps
    completions there), so no locking is needed.
    """

    def __init__(self, capacity: int = 65536, miss_log_cap: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.ring: deque = deque(maxlen=capacity)
        self.emitted = 0          # total events ever emitted
        self.evicted = 0          # events pushed out of the full ring
        # (rid, idx) -> in-flight stamp dict; popped at the terminal span,
        # so steady-state size is bounded by frames in flight.
        self._open: Dict[Tuple[int, int], Dict[str, float]] = {}
        # Per-frame breakdowns of deadline misses (postmortem log).
        self.miss_log: deque = deque(maxlen=miss_log_cap)
        self.miss_log_overflow = 0
        # terminal kind -> (by-category, by-slice) aggregation maps of
        # (scope key -> stage -> seconds). LATE frames answer "which
        # stage ate the slack"; SHED/LOST frames get the partial chain
        # up to their terminal (where did they spend their life before
        # being dropped/destroyed).
        self._attr: Dict[str, Tuple[Dict[str, Dict[str, float]],
                                    Dict[str, Dict[str, float]]]] = {
            LATE: ({}, {}), SHED: ({}, {}), LOST: ({}, {}),
        }
        # Terminal accounting: stage -> count (conservation mirror).
        self.terminals: Dict[str, int] = {}

    # -- hot path ----------------------------------------------------------
    def emit(
        self,
        stage: str,
        t: float,
        rid: int = -1,
        idx: int = -1,
        where: Optional[str] = None,
        cat: Optional[str] = None,
        meta: Optional[Dict] = None,
    ) -> None:
        """Record one span event at time ``t`` (the caller's ``loop.now``
        — virtual or wall, the tracer never reads a clock itself)."""
        ring = self.ring
        if len(ring) == self.capacity:
            self.evicted += 1
        ring.append(SpanEvent(t, stage, rid, idx, where, cat, meta))
        self.emitted += 1
        if rid < 0 or idx < 0:
            return
        slot = _STAMP_STAGES.get(stage)
        if slot is not None:
            stamps = self._open.get((rid, idx))
            if stamps is None:
                stamps = self._open[(rid, idx)] = {}
            # First stamp wins (a retried dispatch re-stamps explicitly).
            if slot == "dispatch":
                stamps[slot] = t
                if meta is not None and "profiled" in meta:
                    stamps["profiled"] = meta["profiled"]
            else:
                stamps.setdefault(slot, t)
                if stage == WIRE_RECV and meta is not None and "sent_at" in meta:
                    stamps.setdefault("send", meta["sent_at"])
        elif stage in TERMINAL_STAGES:
            self.terminals[stage] = self.terminals.get(stage, 0) + 1
            stamps = self._open.pop((rid, idx), None)
            if stage != COMPLETED and stamps:
                self._finalize(stage, t, rid, idx, where, cat, stamps, meta)

    # -- attribution -------------------------------------------------------
    @staticmethod
    def _breakdown(stamps: Dict[str, float], complete: float) -> Tuple[Dict[str, float], float]:
        """Fold a frame's stamps into the per-stage budget breakdown.

        The stages are CONSECUTIVE deltas over the stamp chain
        send -> recv -> deliver/ingest -> window_close -> dispatch ->
        completion (missing hops contribute zero), so their sum
        telescopes exactly to ``complete - first_stamp`` — the observed
        latency. ``device`` is capped at the profiled WCET; the excess
        is ``overrun`` (device + overrun still equals the raw device
        residency, so the telescoping identity is preserved)."""
        send = stamps.get("send")
        recv = stamps.get("recv")
        ingest = stamps.get("ingest")
        deliver = stamps.get("deliver", ingest)
        wclose = stamps.get("window_close")
        dispatch = stamps.get("dispatch")
        # Walk the chain, defaulting each missing hop to its predecessor
        # so every delta is well-defined and non-negative-by-order.
        t0 = send if send is not None else (
            recv if recv is not None else (
                deliver if deliver is not None else (
                    wclose if wclose is not None else (
                        dispatch if dispatch is not None else complete))))
        a = recv if recv is not None else t0
        b = deliver if deliver is not None else a
        c = wclose if wclose is not None else b
        d = dispatch if dispatch is not None else c
        device_raw = complete - d
        profiled = stamps.get("profiled")
        if profiled is not None and math.isfinite(profiled):
            device = min(device_raw, profiled)
            overrun = device_raw - device
        else:
            device, overrun = device_raw, 0.0
        stages = {
            "wire": a - t0,
            "reorder_buffer": b - a,
            "window": c - b,
            "queue": d - c,
            "device": device,
            "overrun": overrun,
        }
        return stages, complete - t0

    def _finalize(
        self,
        stage: str,
        t: float,
        rid: int,
        idx: int,
        where: Optional[str],
        cat: Optional[str],
        stamps: Optional[Dict[str, float]],
        meta: Optional[Dict],
    ) -> None:
        stages, total = self._breakdown(stamps, t)
        if stage == LATE:
            entry = {
                "rid": rid, "idx": idx, "t": t, "cat": cat, "slice": where,
                "total": total, "stages": stages,
            }
            if meta is not None and "overdue" in meta:
                entry["overdue"] = meta["overdue"]
            if len(self.miss_log) == self.miss_log.maxlen:
                self.miss_log_overflow += 1
            self.miss_log.append(entry)
        by_cat, by_slice = self._attr[stage]
        for scope, key in ((by_cat, cat), (by_slice, where)):
            if key is None:
                continue
            agg = scope.get(key)
            if agg is None:
                agg = scope[key] = {s: 0.0 for s in ATTR_STAGES}
                agg["frames"] = 0
                agg["total"] = 0.0
            agg["frames"] += 1
            agg["total"] += total
            for s in ATTR_STAGES:
                agg[s] += stages[s]

    def attribution(self) -> Dict[str, Dict]:
        """Aggregated attribution: per category and per slice, seconds
        spent in each stage (plus frame count and summed observed
        latency). Top-level ``by_category``/``by_slice`` cover deadline
        MISSES (LATE frames); ``shed``/``lost`` carry the partial-chain
        breakdowns for frames dropped at the door or destroyed."""
        late_cat, late_slice = self._attr[LATE]
        out = {
            "by_category": {k: dict(v) for k, v in late_cat.items()},
            "by_slice": {k: dict(v) for k, v in late_slice.items()},
            "terminals": dict(self.terminals),
            "miss_log_overflow": self.miss_log_overflow,
        }
        for kind in (SHED, LOST):
            by_cat, by_slice = self._attr[kind]
            out[kind] = {
                "by_category": {k: dict(v) for k, v in by_cat.items()},
                "by_slice": {k: dict(v) for k, v in by_slice.items()},
            }
        return out

    def frame_spans(self, rid: int, idx: int) -> List[SpanEvent]:
        """All ring-resident events for one frame, in emit order."""
        return [e for e in self.ring if e.rid == rid and e.idx == idx]

    # -- export ------------------------------------------------------------
    def chrome_trace(self) -> Dict:
        """The ring as Chrome ``trace_event`` JSON (load in
        ``chrome://tracing`` or Perfetto). Device completions become
        duration ("X") slices spanning their execution; every other
        event is an instant ("i") on its frame's thread lane."""
        events: List[Dict] = []
        for ev in self.ring:
            args: Dict = {"frame": ev.idx}
            if ev.cat is not None:
                args["category"] = ev.cat
            if ev.meta:
                args.update(ev.meta)
            rec = {
                "name": ev.stage,
                "ts": ev.t * 1e6,  # trace_event wants microseconds
                "pid": ev.where or "system",
                "tid": f"req{ev.rid}" if ev.rid >= 0 else ev.stage,
                "args": args,
            }
            dur = ev.meta.get("dur") if ev.meta else None
            if ev.stage == DEVICE_COMPLETE and dur is not None:
                rec["ph"] = "X"
                rec["ts"] = (ev.t - dur) * 1e6
                rec["dur"] = dur * 1e6
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            events.append(rec)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def snapshot(self) -> Dict:
        """JSON-able tracer state summary for the unified snapshot."""
        return {
            "capacity": self.capacity,
            "events": len(self.ring),
            "emitted": self.emitted,
            "evicted": self.evicted,
            "open_frames": len(self._open),
            "attribution": self.attribution(),
        }


# ---------------------------------------------------------------------------
# Streaming percentiles
# ---------------------------------------------------------------------------

class LatencyHistogram:
    """Fixed log-bucket streaming histogram: p50/p95/p99 without samples.

    Buckets are geometric with ratio ``growth`` over
    ``[min_value, max_value)`` plus an underflow bucket (values below
    ``min_value``, including zero) and an overflow bucket. ``record`` is
    O(1); memory is a fixed ~``log(max/min)/log(growth)`` ints
    regardless of how many values stream through. ``percentile`` returns
    the UPPER edge of the bucket holding the requested rank, so the
    estimate is conservative and within one growth factor of the exact
    sample percentile (the property test's bound); exact ``sum``/``min``
    /``max`` are tracked alongside, so means stay exact.
    """

    __slots__ = ("min_value", "growth", "_log_growth", "_nb", "counts",
                 "n", "total", "vmin", "vmax")

    def __init__(self, min_value: float = 1e-6, max_value: float = 1e5,
                 growth: float = 1.08):
        if not (min_value > 0 and max_value > min_value and growth > 1.0):
            raise ValueError(
                f"bad histogram bounds: [{min_value}, {max_value}) x{growth}"
            )
        self.min_value = min_value
        self.growth = growth
        self._log_growth = math.log(growth)
        self._nb = int(math.ceil(math.log(max_value / min_value) / self._log_growth))
        # counts[0] = underflow, counts[1.._nb] = log buckets,
        # counts[_nb + 1] = overflow.
        self.counts = [0] * (self._nb + 2)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v: float) -> None:
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v < self.min_value:
            self.counts[0] += 1
            return
        i = int(math.log(v / self.min_value) / self._log_growth) + 1
        if i > self._nb:
            i = self._nb + 1
        self.counts[i] += 1

    def merge(self, other: "LatencyHistogram") -> None:
        """Fold ``other`` into this histogram (bucket layouts must match
        — everything this repo builds uses the defaults)."""
        if (other.min_value, other.growth, other._nb) != (
                self.min_value, self.growth, self._nb):
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def _bucket_upper(self, i: int) -> float:
        if i == 0:
            return min(self.min_value, self.vmax)
        if i > self._nb:
            return self.vmax
        return min(self.min_value * self.growth ** i, self.vmax)

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]: the upper edge of the
        bucket containing the ``ceil(q * n)``-th smallest sample,
        clamped to the exact observed max."""
        if self.n == 0:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        rank = max(1, int(math.ceil(q * self.n)))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self._bucket_upper(i)
        return self.vmax  # unreachable; defensive

    def to_dict(self) -> Dict[str, float]:
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin if self.n else 0.0,
            "max": self.vmax if self.n else 0.0,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


# ---------------------------------------------------------------------------
# Text exposition
# ---------------------------------------------------------------------------

def _sanitize(part: str) -> str:
    return "".join(ch if ch.isalnum() else "_" for ch in str(part))


def render_text(snapshot: Dict, prefix: str = "deeprt") -> str:
    """Flatten a JSON snapshot into ``/metrics``-style exposition lines:
    one ``<prefix>_<path> <value>`` line per numeric/boolean leaf, paths
    sorted, so the cluster snapshot scrapes like a Prometheus target."""
    lines: List[str] = []

    def walk(path: str, node) -> None:
        if isinstance(node, dict):
            for k in sorted(node, key=str):
                walk(f"{path}_{_sanitize(k)}", node[k])
        elif isinstance(node, bool):
            lines.append(f"{path} {int(node)}")
        elif isinstance(node, (int, float)):
            v = float(node)
            if math.isfinite(v):
                lines.append(f"{path} {node}")
        # strings / lists are annotations, not metrics: skipped.

    walk(prefix, snapshot)
    return "\n".join(lines) + "\n"
