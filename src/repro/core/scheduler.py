"""DeepRT: the assembled scheduler (paper Fig. 1).

Wiring:

  clients --requests--> AdmissionControl --admitted--> DisBatcher
  DisBatcher --job instances--> EDFWorker(deadline queue) --> device
  EDFWorker --overruns--> AdaptationModule --shape override--> DisBatcher

The same object drives a virtual clock (simulation: benchmarks, admission
accuracy studies) or a wall clock with a real execution backend (live
serving over jit-compiled JAX steps — see ``serving/batcher_bridge.py``).

Non-real-time requests (paper §3.3): bypass the admission test, use the
large DisBatcher window (low deadline priority under EDF), carry an
imposed minimum period, and have a batch-size cap so a non-RT job cannot
block RT jobs for long (non-preemptive blocking is bounded by one job).
The Phase-2 imitator start time already covers in-flight blocking because
the device's busy-until is part of the recorded system state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core import telemetry as T
from repro.core.adaptation import AdaptationModule, default_shrink
from repro.core.admission import (
    AdmissionControl,
    AdmissionResult,
    phase1_from_scheduler,
    snapshot_from_scheduler,
)
from repro.core.disbatcher import DisBatcher
from repro.core.edf import ChunkPolicy, EDFWorker
from repro.core.profiler import ProfileTable
from repro.core.request import Category, ChunkJob, Frame, JobInstance, Request
from repro.core.simulator import EventLoop, Metrics, SequentialDevice

NONRT_MIN_PERIOD = 1.0  # imposed arrival period for non-RT requests (§3.3)
NONRT_BATCH_CAP = 8  # bounds priority inversion from one non-RT job


@dataclass
class ExecutionModel:
    """How "actual" execution time is produced.

    simulation: ``actual_fn(job, profiled_wcet) -> seconds``. Defaults to
    a deterministic 0.97x of profiled WCET (profiles are p99, reality sits
    just below). Benchmarks override this with samplers / overrun
    injectors. Live serving passes the identity (the profiled WCET): the
    value only seeds the AsyncDevice's ``busy_until`` estimate — the real
    completion instant comes from the hardware, never from this model.
    (The legacy blocking mode that ran the compiled step inside
    ``actual_fn`` is deleted; there is no synchronous execution path.)
    """

    actual_fn: Callable[[JobInstance, float], float] = (
        lambda job, wcet: 0.97 * wcet
    )


class DeepRT:
    def __init__(
        self,
        table: ProfileTable,
        loop: Optional[EventLoop] = None,
        execution: Optional[ExecutionModel] = None,
        adaptation_enabled: bool = True,
        shrink_fn=default_shrink,
        utilization_bound: float = 1.0,
        early_flush: bool = True,
        device=None,
    ):
        """``early_flush`` enables the paper's idle-device optimization
        (§4.3). It is guarded (see DisBatcher.flush_early) so Theorem 1's
        guarantee holds empirically (0 misses across 30k random workloads
        / 2.6M frames), but it can perturb the EDF order relative to the
        Phase-2 imitator's timeline by up to one job's non-preemptive
        blocking, so per-frame latency *predictions* are only strictly
        conservative with ``early_flush=False`` (strict mode).

        ``device`` swaps the execution backend behind the shared device
        contract (see ``simulator.SequentialDevice``): ``None`` builds a
        simulated ``SequentialDevice``; live serving passes an
        ``AsyncDevice`` so the loop never blocks on XLA."""
        self.loop = loop if loop is not None else EventLoop()
        self.table = table
        self.execution = execution if execution is not None else ExecutionModel()
        self.utilization_bound = utilization_bound
        self.early_flush = early_flush
        # Non-RT jobs bypass admission, so their batch is bounded here
        # rather than by the imitator; an execution backend with a hard
        # batch ceiling (the decode slot arena) lowers this to its
        # capacity (see serving/batcher_bridge.build_live_scheduler).
        self.nonrt_batch_cap = NONRT_BATCH_CAP
        self.metrics = Metrics()

        if device is None:
            device = SequentialDevice(self.loop, on_idle=self._on_device_idle)
        else:
            device.on_idle = self._on_device_idle
        self.device = device
        self.worker = EDFWorker(
            loop=self.loop,
            device=self.device,
            exec_time_fn=self._exec_time,
            profiled_fn=self._profiled,
            metrics=self.metrics,
            request_idle_work=self._idle_flush,
            next_rt_release_fn=lambda: self.disbatcher.earliest_next_joint(
                realtime_only=True
            ),
        )
        self.disbatcher = DisBatcher(self.loop, emit=self.worker.submit)
        self.admission = AdmissionControl(table)
        self.adaptation = AdaptationModule(
            table, self.disbatcher, shrink_fn=shrink_fn, enabled=adaptation_enabled
        )
        self.worker.on_job_complete = self.adaptation.on_job_complete
        # Multi-step decode chunking auto-enables when the table carries a
        # chunk family for any category (i.e. the engine was profiled per
        # depth). Both substrates key off the same table state, so a
        # simulated DeepRT and its live twin make identical depth choices
        # on identical traces — the determinism property the differential
        # harness asserts.
        if table.has_any_chunks():
            self.worker.chunk_policy = ChunkPolicy.from_table(table)
        self.admitted: List[Request] = []
        self.rejected: List[Request] = []
        # Frame-lifecycle tracer (core/telemetry.py); attach_tracer wires
        # the whole pipeline (DisBatcher, EDF worker) in one call.
        self.tracer = None
        self.tracer_tag: Optional[str] = None

    def attach_tracer(self, tracer, tag: Optional[str] = None) -> None:
        """Enable frame-lifecycle tracing across this scheduler's whole
        pipeline. ``tag`` labels the events (the slice name in a
        cluster). ``tracer=None`` detaches — tracing reverts to the
        zero-cost off path."""
        self.tracer = tracer
        self.tracer_tag = tag
        self.worker.tracer = tracer
        self.worker.tracer_tag = tag
        self.disbatcher.tracer = tracer
        self.disbatcher.tracer_tag = tag
        # Devices that carry a measured-completion lane (AsyncDevice —
        # possibly behind a FaultyDevice wrapper) get the tracer too;
        # SequentialDevice defines no ``tracer`` slot and is skipped.
        for dev in (self.device, getattr(self.device, "inner", None)):
            if dev is not None and "tracer" in getattr(dev, "__dict__", {}):
                dev.tracer = tracer
                dev.tracer_tag = tag

    # ----- execution-time plumbing ---------------------------------------
    def _profiled(self, job) -> float:
        if isinstance(job, ChunkJob):
            # The fused dispatch charges the k-step family WCET — to
            # busy_until, the watchdog's expected time, and (via the
            # worker's queued-WCET total before fusing) the gateway's
            # delay estimate.
            return self.table.chunk_wcet(
                job.category.model_id, job.shape_key, job.k
            )
        return self.table.wcet(job.category.model_id, job.shape_key, job.batch_size)

    def _exec_time(self, job: JobInstance) -> float:
        return self.execution.actual_fn(job, self._profiled(job))

    def _on_device_idle(self) -> None:
        self.worker.on_device_idle()

    def _idle_flush(self) -> bool:
        if not self.early_flush:
            return False
        return self.disbatcher.flush_early(
            wcet_fn=lambda cat, shape, b: self.table.wcet(cat.model_id, shape, b)
        )

    def utilization(self) -> float:
        """Current Phase-1 utilization — what the cluster placement loop
        ranks slices by (lowest first) and what its per-slice
        utilization-bound invariant is asserted against."""
        return phase1_from_scheduler(self)

    # ----- client API ------------------------------------------------------
    def submit_request(
        self, request: Request, external_arrivals: bool = False
    ) -> AdmissionResult:
        """Admission-test a pending request at the current time; admit on
        success. ``request.start_time`` below now is clamped to now.

        ``external_arrivals=True`` registers the admitted request with
        the DisBatcher but schedules NO synthetic arrival events: the
        caller (the ingest gateway) owns the frame path and delivers
        real payload-carrying frames via ``ingest_frame``. Admission
        still models the request at its declared period — the gateway's
        load shedder is what reconciles declared rate with reality.
        """
        now = self.loop.now
        if request.start_time < now:
            request.start_time = now
        if not request.category.realtime:
            request.period = max(request.period, NONRT_MIN_PERIOD)
            self._admit(request, external_arrivals)
            return AdmissionResult(admitted=True, phase=0, utilization=0.0,
                                   reason="non-RT: admission bypassed")
        state = snapshot_from_scheduler(
            now=now,
            disbatcher=self.disbatcher,
            queued_jobs=self.worker.queue.snapshot(),
            device_free_at=self.device.busy_until or now,
            table=self.table,
            pending=request,
        )
        result = self.admission.admit(state, self.utilization_bound)
        if result.admitted:
            self._admit(request, external_arrivals)
        else:
            self.rejected.append(request)
        if self.tracer is not None:
            self.tracer.emit(
                T.ADMISSION, now, where=self.tracer_tag,
                cat=str(request.category),
                meta={"request_id": request.request_id,
                      "admitted": result.admitted, "phase": result.phase,
                      "utilization": result.utilization})
        return result

    def _admit(self, request: Request, external_arrivals: bool = False) -> None:
        self.admitted.append(request)
        self.disbatcher.add_request(request)
        if external_arrivals:
            return  # the gateway drives ingest_frame itself
        for i in range(request.n_frames):
            arrival = request.frame_arrival(i)
            self.loop.schedule(
                arrival,
                self._make_arrival(request, i),
                priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
            )

    def _make_arrival(self, request: Request, index: int):
        def _arrive() -> None:
            self.ingest_frame(request, index)
        return _arrive

    def ingest_frame(
        self,
        request: Request,
        index: int,
        payload=None,
        ingest_time: Optional[float] = None,
    ) -> Optional[Frame]:
        """Deliver one frame of an admitted request AT ARRIVAL TIME.

        THE frame entry point — the internal periodic arrivals and the
        ingest gateway's real payload-carrying deliveries both land
        here, so deadline stamping happens at arrival (now +
        relative_deadline), never at dispatch. ``payload`` rides the
        frame to the engine's staging ring; ``ingest_time`` (default:
        now) is when the bytes entered the gateway, the origin for
        end-to-end latency.
        """
        now = self.loop.now
        if getattr(self.device, "closed", False):
            # The slice died. A frame delivered after that can never
            # complete here (the failover tail re-admitted elsewhere
            # serves the stream's future); feeding it to the DisBatcher
            # would count it delivered-and-then-silently-vanished. Count
            # it delivered AND lost so conservation stays falsifiable:
            # completed + dropped + lost == ingested.
            self.metrics.record_ingest()
            self.metrics.record_lost()
            if self.tracer is not None:
                self.tracer.emit(
                    T.LOST, now, request.request_id, index,
                    where=self.tracer_tag, cat=str(request.category),
                    meta={"reason": "device_closed"})
            return None
        frame = Frame(
            request_id=request.request_id,
            category=request.category,
            index=index,
            arrival_time=now,
            deadline=now + request.relative_deadline,
            payload=payload,
            ingest_time=now if ingest_time is None else ingest_time,
        )
        self.disbatcher.on_frame(frame)
        self.metrics.record_ingest()
        if self.tracer is not None:
            self.tracer.emit(
                T.INGEST, now, request.request_id, index,
                where=self.tracer_tag, cat=str(request.category),
                meta={"deadline": frame.deadline,
                      "ingest_time": frame.ingest_time})
        if not request.category.realtime:
            pending = self.disbatcher.pending_frames(request.category)
            if len(pending) >= self.nonrt_batch_cap:
                self.disbatcher._flush(request.category, now)
        # Non-idling: an idle device should not sit on waiting frames.
        if self.device.idle and not self.worker.queue:
            self.worker.on_device_idle()
        return frame

    # ----- run --------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> Metrics:
        self.loop.run(until)
        return self.metrics
