"""DeepRT core: the paper's contribution as a composable library."""
from repro.core.adaptation import AdaptationModule, default_shrink
from repro.core.admission import (
    AdmissionControl,
    AdmissionResult,
    CategorySnapshot,
    SystemState,
    phase1_from_scheduler,
    snapshot_from_scheduler,
)
from repro.core.baselines import AIMD, BATCH, BATCHDelay, SEDF
from repro.core.bucketing import (
    bucket,
    bucket_sizes,
    padding_fraction,
    slice_arena_slots,
)
from repro.core.cluster import ClusterScheduler, LiveSlice, Slice, SliceSpec
from repro.core.disbatcher import WINDOW_FRACTION, DisBatcher
from repro.core.edf import DeadlineQueue, EDFWorker
from repro.core.profiler import (
    AnalyticProfiler,
    HardwareSpec,
    MeasuredProfiler,
    ProfileTable,
)
from repro.core.request import Category, Frame, JobInstance, PseudoJob, Request
from repro.core.scheduler import DeepRT, ExecutionModel
from repro.core.simulator import (
    EventLoop,
    Metrics,
    ProcessorSharingDevice,
    SequentialDevice,
    WallClock,
)
from repro.core.traces import DESKTOP_TRACES, JETSON_TRACES, TraceSpec, generate_trace

__all__ = [
    "AdaptationModule",
    "default_shrink",
    "AdmissionControl",
    "AdmissionResult",
    "CategorySnapshot",
    "SystemState",
    "phase1_from_scheduler",
    "snapshot_from_scheduler",
    "AIMD",
    "BATCH",
    "BATCHDelay",
    "SEDF",
    "bucket",
    "bucket_sizes",
    "padding_fraction",
    "slice_arena_slots",
    "ClusterScheduler",
    "LiveSlice",
    "Slice",
    "SliceSpec",
    "WINDOW_FRACTION",
    "DisBatcher",
    "DeadlineQueue",
    "EDFWorker",
    "AnalyticProfiler",
    "HardwareSpec",
    "MeasuredProfiler",
    "ProfileTable",
    "Category",
    "Frame",
    "JobInstance",
    "PseudoJob",
    "Request",
    "DeepRT",
    "ExecutionModel",
    "EventLoop",
    "Metrics",
    "ProcessorSharingDevice",
    "SequentialDevice",
    "WallClock",
    "DESKTOP_TRACES",
    "JETSON_TRACES",
    "TraceSpec",
    "generate_trace",
]
