"""Baseline schedulers the paper compares against (§6.2, §6.3).

- AIMD       — Clipper/MArk adaptive batching: additive batch-size increase
               while latency meets the objective, multiplicative decrease on
               violation. Categories execute *concurrently* (one virtual
               model instance per category, processor-sharing device).
- BATCH      — Triton static batching: fixed batch size per category,
               execute as soon as the batch fills. Concurrent.
- BATCHDelay — Triton with max queue delay: fixed batch size OR timeout,
               whichever first. Concurrent.
- SEDF       — Sequential EDF: per-frame jobs (no batching) on a sequential
               device, EDF order, with an EDF-imitator admission test
               (paper §6.3 builds exactly this as the RT comparator).

All baselines run on the same event loop / trace / profiler inputs as
DeepRT, and produce the same Metrics, so the benchmark harness is a strict
apples-to-apples reproduction of the paper's comparison methodology. The
processor-sharing device reproduces the paper's Fig-2a observation that
concurrent CUDA contexts time-slice (execution time grows ~linearly in the
number of resident jobs).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.admission import AdmissionControl
from repro.core.profiler import ProfileTable
from repro.core.request import Category, Frame, PseudoJob, Request
from repro.core.simulator import (
    EventLoop,
    Metrics,
    ProcessorSharingDevice,
    SequentialDevice,
)


@dataclass
class _BatchJob:
    category: Category
    frames: List[Frame]
    created: float

    @property
    def batch_size(self) -> int:
        return len(self.frames)


class _ConcurrentBaseline:
    """Shared machinery for AIMD / BATCH / BATCH-Delay."""

    def __init__(
        self,
        table: ProfileTable,
        loop: Optional[EventLoop] = None,
        actual_fn: Optional[Callable] = None,
        interference: float = 1.0,
    ):
        self.loop = loop if loop is not None else EventLoop()
        self.table = table
        self.device = ProcessorSharingDevice(self.loop, interference=interference)
        self.metrics = Metrics()
        self.actual_fn = actual_fn or (lambda job, wcet: 0.97 * wcet)
        self._queues: Dict[Category, List[Frame]] = {}
        self._busy: Dict[Category, bool] = {}
        self.admitted: List[Request] = []
        self.job_bytes_fn = None  # optional: job -> bytes (Fig 6 benchmark)

    # Baselines have no admission control (paper §6.2).
    def submit_request(self, request: Request) -> None:
        self.admitted.append(request)
        self._queues.setdefault(request.category, [])
        self._busy.setdefault(request.category, False)
        for i in range(request.n_frames):
            self.loop.schedule(
                request.frame_arrival(i), self._make_arrival(request, i)
            )

    def _make_arrival(self, request: Request, index: int):
        def _arrive() -> None:
            frame = Frame(
                request_id=request.request_id,
                category=request.category,
                index=index,
                arrival_time=self.loop.now,
                deadline=self.loop.now + request.relative_deadline,
            )
            self._queues[request.category].append(frame)
            self._poll(request.category)
        return _arrive

    def _poll(self, cat: Category) -> None:
        raise NotImplementedError

    def _launch(self, cat: Category, frames: List[Frame]) -> None:
        job = _BatchJob(cat, frames, self.loop.now)
        wcet = self.table.wcet(cat.model_id, cat.shape_key, len(frames))
        actual = self.actual_fn(job, wcet)
        self._busy[cat] = True
        jb = self.job_bytes_fn(job) if self.job_bytes_fn is not None else 0.0
        self.device.submit(job, actual, self._on_complete, job_bytes=jb)

    def _on_complete(self, job: _BatchJob, now: float) -> None:
        self.metrics.record_job(job.batch_size)
        for f in job.frames:
            f.completion_time = now
            self.metrics.record_frame(f)
        self._busy[job.category] = False
        self._after_complete(job, now)
        self._poll(job.category)

    def _after_complete(self, job: _BatchJob, now: float) -> None:
        pass

    def run(self, until: Optional[float] = None) -> Metrics:
        self.loop.run(until)
        return self.metrics


class AIMD(_ConcurrentBaseline):
    """Clipper-style AIMD adaptive batching (paper baseline #1)."""

    def __init__(self, *args, additive: int = 1, multiplicative: float = 2.0, **kw):
        super().__init__(*args, **kw)
        self.additive = additive
        self.multiplicative = multiplicative
        self._batch_size: Dict[Category, int] = {}
        self._slo: Dict[Category, float] = {}

    def submit_request(self, request: Request) -> None:
        cat = request.category
        self._batch_size.setdefault(cat, 1)
        slo = self._slo.get(cat, float("inf"))
        self._slo[cat] = min(slo, request.relative_deadline)
        super().submit_request(request)

    def _poll(self, cat: Category) -> None:
        q = self._queues[cat]
        if not q or self._busy[cat]:
            return
        b = min(self._batch_size[cat], len(q))
        frames, self._queues[cat] = q[:b], q[b:]
        self._launch(cat, frames)

    def _after_complete(self, job: _BatchJob, now: float) -> None:
        cat = job.category
        # Latency of the batch = oldest member frame's response time.
        latency = max(now - f.arrival_time for f in job.frames)
        if latency <= self._slo.get(cat, float("inf")):
            self._batch_size[cat] = self._batch_size[cat] + self.additive
        else:
            self._batch_size[cat] = max(
                1, int(self._batch_size[cat] / self.multiplicative)
            )


class BATCH(_ConcurrentBaseline):
    """Triton static batching: run when ``batch_size`` frames accumulate.

    Also fires a partial batch when no more frames can ever arrive for the
    category (end of trace), so runs terminate.
    """

    def __init__(self, *args, batch_size: int = 4, **kw):
        super().__init__(*args, **kw)
        self.batch_size = batch_size
        self._last_arrival: Dict[Category, float] = {}

    def submit_request(self, request: Request) -> None:
        cat = request.category
        last = self._last_arrival.get(cat, 0.0)
        self._last_arrival[cat] = max(last, request.end_time)
        super().submit_request(request)
        # Drain stragglers after the last possible arrival.
        self.loop.schedule(
            self._last_arrival[cat] + 1e-6, lambda: self._poll(cat, drain=True)
        )

    def _poll(self, cat: Category, drain: bool = False) -> None:
        q = self._queues[cat]
        if self._busy[cat] or not q:
            return
        drain = drain or self.loop.now >= self._last_arrival.get(cat, 0.0)
        if len(q) >= self.batch_size or (drain and q):
            b = min(self.batch_size, len(q))
            frames, self._queues[cat] = q[:b], q[b:]
            self._launch(cat, frames)


class BATCHDelay(BATCH):
    """Triton with max queue delay: batch fills OR timeout expires."""

    def __init__(self, *args, batch_size: int = 4, max_delay: float = 0.05, **kw):
        super().__init__(*args, batch_size=batch_size, **kw)
        self.max_delay = max_delay

    def _make_arrival(self, request: Request, index: int):
        base = super()._make_arrival(request, index)

        def _arrive() -> None:
            base()
            cat = request.category
            # A timeout anchored to this frame's arrival.
            self.loop.schedule_in(self.max_delay, lambda: self._timeout(cat))
        return _arrive

    def _timeout(self, cat: Category) -> None:
        q = self._queues[cat]
        if q and not self._busy[cat]:
            oldest = min(f.arrival_time for f in q)
            if self.loop.now - oldest >= self.max_delay - 1e-9:
                b = min(self.batch_size, len(q))
                frames, self._queues[cat] = q[:b], q[b:]
                self._launch(cat, frames)


class SEDF:
    """Sequential EDF without batching (paper §6.3's RT comparator)."""

    def __init__(
        self,
        table: ProfileTable,
        loop: Optional[EventLoop] = None,
        actual_fn: Optional[Callable] = None,
    ):
        self.loop = loop if loop is not None else EventLoop()
        self.table = table
        self.metrics = Metrics()
        self.actual_fn = actual_fn or (lambda job, wcet: 0.97 * wcet)
        self.device = SequentialDevice(self.loop, on_idle=self._maybe_start)
        self._queue: List = []  # heap of (deadline, seq, frame)
        self._seq = 0
        self.admission = AdmissionControl(table)
        self.admitted: List[Request] = []
        self.rejected: List[Request] = []

    # -- admission: EDF imitator over per-frame pseudo jobs ---------------
    def _pseudo_jobs(self, requests: List[Request], now: float) -> List[PseudoJob]:
        jobs = []
        for r in requests:
            e = self.table.wcet(r.category.model_id, r.category.shape_key, 1)
            first = 0
            if r.start_time < now:
                first = int(math.ceil((now - r.start_time) / r.period))
            for i in range(first, r.n_frames):
                a = r.frame_arrival(i)
                jobs.append(
                    PseudoJob(
                        category=r.category,
                        release_time=a,
                        exec_time=e,
                        relative_deadline=r.relative_deadline,
                        n_frames=1,
                        frame_refs=((a, a + r.relative_deadline, r.request_id, i),),
                    )
                )
        jobs.sort(key=lambda j: (j.release_time, j.deadline))
        return jobs

    def submit_request(self, request: Request) -> bool:
        now = self.loop.now
        if request.start_time < now:
            request.start_time = now
        live = [r for r in self.admitted if r.end_time >= now]
        jobs = self._pseudo_jobs(live + [request], now)
        # Frames already queued:
        for dl, _, f in self._queue:
            e = self.table.wcet(f.category.model_id, f.category.shape_key, 1)
            jobs.append(PseudoJob(f.category, now, e, dl - now, 1))
        jobs.sort(key=lambda j: (j.release_time, j.deadline))
        ok, _ = self.admission.edf_imitator(
            jobs, start_time=max(now, self.device.busy_until or now)
        )
        if not ok:
            self.rejected.append(request)
            return False
        self.admitted.append(request)
        for i in range(request.n_frames):
            self.loop.schedule(
                request.frame_arrival(i), self._make_arrival(request, i)
            )
        return True

    def _make_arrival(self, request: Request, index: int):
        def _arrive() -> None:
            f = Frame(
                request_id=request.request_id,
                category=request.category,
                index=index,
                arrival_time=self.loop.now,
                deadline=self.loop.now + request.relative_deadline,
            )
            heapq.heappush(self._queue, (f.deadline, self._seq, f))
            self._seq += 1
            self._maybe_start()
        return _arrive

    def _maybe_start(self) -> None:
        if not self.device.idle or not self._queue:
            return
        _, _, f = heapq.heappop(self._queue)
        wcet = self.table.wcet(f.category.model_id, f.category.shape_key, 1)
        actual = self.actual_fn(f, wcet)
        self.device.submit(f, actual, self._on_complete)

    def _on_complete(self, frame: Frame, now: float) -> None:
        frame.completion_time = now
        self.metrics.record_job(1)
        self.metrics.record_frame(frame)

    def run(self, until: Optional[float] = None) -> Metrics:
        self.loop.run(until)
        return self.metrics
