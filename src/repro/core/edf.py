"""Non-idling, non-preemptive EDF execution (paper §3.3, §4.3).

The Worker consumes a deadline-ordered priority queue of job instances and
executes them one at a time on a sequential device. Non-idling: whenever
the device goes idle and the queue is non-empty, the earliest-deadline job
starts immediately; if the queue is empty but frames are waiting in the
DisBatcher, the early-flush optimization fires.

The Worker is also the monitoring point (paper §4.3): it records deadline
misses and reports overruns (actual execution time exceeding the profiled
WCET) to the Adaptation Module.
"""
from __future__ import annotations

import heapq
import math
import time as _time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core import telemetry as T
from repro.core.bucketing import bucket
from repro.core.faults import TransientSubmitError
from repro.core.request import ChunkJob, JobInstance
from repro.core.simulator import Metrics

#: Retained fused-dispatch decisions (``EDFWorker.chunk_log``). A live
#: worker dispatches for the process lifetime, so the audit trail is a
#: capped deque: old entries evict (counted in ``chunk_log_overflow``).
CHUNK_LOG_CAP = 4096


class DeadlineQueue:
    """Priority queue keyed on absolute deadline (ties: creation order)."""

    def __init__(self):
        self._heap: List[JobInstance] = []

    def push(self, job: JobInstance) -> None:
        heapq.heappush(self._heap, job)

    def pop(self) -> JobInstance:
        return heapq.heappop(self._heap)

    def peek(self) -> JobInstance:
        return self._heap[0]

    def pop_earliest_realtime(self) -> Optional[JobInstance]:
        """Pop the earliest-deadline REAL-TIME job, if any (O(n) scan;
        queues are short). Used when the head is a deferred non-RT job."""
        rt = [j for j in self._heap if j.category.realtime]
        if not rt:
            return None
        target = min(rt)
        self._heap.remove(target)
        heapq.heapify(self._heap)
        return target

    def remove(self, job: JobInstance) -> None:
        """Remove a specific queued job (O(n); used when the worker fuses
        the next k-1 same-category jobs into a decode chunk)."""
        self._heap.remove(job)
        heapq.heapify(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def snapshot(self) -> List[JobInstance]:
        """Jobs currently queued, in deadline order (for admission §4.2)."""
        return sorted(self._heap)


@dataclass
class ChunkPolicy:
    """Slack-driven decode chunk-depth selection for the EDF worker.

    When the earliest-deadline job is a chunkable decode job and the next
    queued jobs continue the same category in deadline order, the worker
    may fuse up to ``max(depths)`` of them into one k-step scanned
    dispatch — IF the head job's deadline slack covers the chunk's full
    profiled WCET plus a safety margin:

        deadline(head) - now >= WCET_chunk(k) + margin

    Near deadlines the rule degenerates to k=1 (plain dispatch); with
    ample slack it picks the deepest profiled depth the queue run-length
    supports. The fused jobs are CONSECUTIVE in deadline order, so EDF
    order is never inverted — a chunk only delays jobs that would have
    waited behind its members anyway, and only by slack the rule proved
    the head could spare. Every inner job's own deadline must also clear
    the chunk (inner deadlines >= head's, head's clears by construction,
    but later members released in the same windows are re-checked so a
    tight straggler degrades the depth rather than miss).
    """

    # job -> True when the category has a chunked program family.
    eligible_fn: Callable[[JobInstance], bool]
    # job -> profiled chunk depths, ascending (must include 1).
    depths_fn: Callable[[JobInstance], List[int]]
    # (job, k) -> profiled WCET of the k-step chunk.
    wcet_fn: Callable[[JobInstance, int], float]
    # job -> safety margin (seconds) the slack must clear on top of the
    # chunk WCET. Default policy: one 1-step WCET of headroom.
    margin_fn: Callable[[JobInstance], float]

    @classmethod
    def from_table(cls, table, margin_steps: float = 1.0) -> "ChunkPolicy":
        """The standard policy over a ProfileTable's chunk families.

        ``margin_steps`` scales the safety margin in units of the
        category's 1-step WCET (default: one step of headroom, so a
        chunk never eats the last step's worth of slack).
        """

        def eligible(job: JobInstance) -> bool:
            return job.category.realtime and table.has_chunks(
                job.category.model_id, job.shape_key
            )

        def depths(job: JobInstance) -> List[int]:
            return table.chunk_depths_profiled(job.category.model_id, job.shape_key)

        def wcet(job: JobInstance, k: int) -> float:
            return table.chunk_wcet(job.category.model_id, job.shape_key, k)

        def margin(job: JobInstance) -> float:
            return margin_steps * table.wcet(
                job.category.model_id, job.shape_key, job.batch_size
            )

        return cls(
            eligible_fn=eligible, depths_fn=depths, wcet_fn=wcet, margin_fn=margin
        )


class EDFWorker:
    """Sequential EDF executor + performance monitor.

    Parameters
    ----------
    device:
        ``SequentialDevice`` — executes one job at a time.
    exec_time_fn:
        job -> actual execution seconds. In simulation this samples the
        "real" execution time (possibly above the profiled WCET: an
        overrun); in live serving it returns the profiled WCET, which
        only seeds the async device's ``busy_until`` estimate (the
        device itself reports the real completion instant).
    profiled_fn:
        job -> profiled WCET seconds (the lookup-table value).
    on_overrun:
        callback(job, excess_seconds) — wired to the Adaptation Module.
    on_underrun:
        callback(job, saved_seconds) — repays adaptation penalty.
    """

    def __init__(
        self,
        loop,
        device,
        exec_time_fn: Callable[[JobInstance], float],
        profiled_fn: Callable[[JobInstance], float],
        metrics: Optional[Metrics] = None,
        on_overrun: Optional[Callable[[JobInstance, float], None]] = None,
        on_underrun: Optional[Callable[[JobInstance, float], None]] = None,
        on_job_complete: Optional[Callable[[JobInstance, float], None]] = None,
        request_idle_work: Optional[Callable[[], bool]] = None,
        next_rt_release_fn: Optional[Callable[[], Optional[float]]] = None,
    ):
        self.loop = loop
        self.device = device
        self.queue = DeadlineQueue()
        self.exec_time_fn = exec_time_fn
        self.profiled_fn = profiled_fn
        self.metrics = metrics if metrics is not None else Metrics()
        self.on_overrun = on_overrun
        self.on_underrun = on_underrun
        self.on_job_complete = on_job_complete
        self.request_idle_work = request_idle_work
        self.next_rt_release_fn = next_rt_release_fn
        self.job_bytes_fn: Optional[Callable[[JobInstance], float]] = None
        # job -> batch-slot rows the execution backend actually ran.
        # Default: the power-of-two prefill bucket. The live bridge
        # overrides it for slot-arena decode, which always executes
        # max_slots rows regardless of the job's batch size.
        self.executed_rows_fn: Optional[Callable[[JobInstance], int]] = None
        self.completed_jobs: List[JobInstance] = []
        # Backoff before re-submitting after a transient device error
        # (seconds; virtual under EventLoop, real under WallClock).
        self.submit_retry_delay = 0.005
        self._retry_scheduled = False  # a future-time retry is pending
        self._dispatch_pending = False  # a same-instant dispatch is pending
        # Running WCET total of queued (not yet started) jobs — O(1)
        # backpressure input for the ingest gateway's per-frame shed
        # decision (summing the queue per arriving frame would be
        # O(queue) on the arrival hot path).
        self.queued_wcet = 0.0
        # Multi-step decode chunking (None = disabled, always k=1).
        self.chunk_policy: Optional[ChunkPolicy] = None
        # (dispatch time, chosen depth, head job_id) per fused dispatch —
        # the determinism harness compares this sequence across the
        # simulated and live substrates. Bounded (see CHUNK_LOG_CAP);
        # evictions are counted, and the O(1) depth histogram below keeps
        # the full-run depth distribution regardless of eviction.
        self.chunk_log: Deque[Tuple[float, int, int]] = deque(maxlen=CHUNK_LOG_CAP)
        self.chunk_log_overflow = 0
        self.chunk_depth_counts: Dict[int, int] = {}
        # Frame-lifecycle tracer (core/telemetry.py). None = tracing off:
        # every hook below is a single identity check on the hot path.
        self.tracer = None
        self.tracer_tag: Optional[str] = None  # slice name in a cluster

    # ----- queue interface (DisBatcher emit target) ---------------------
    def submit(self, job: JobInstance) -> None:
        # Snapshot the charge so the decrement at pop matches even if
        # the table is rescaled (mark_slow) while the job is queued.
        # Non-finite WCETs (a flat entry's inf for an unservable batch)
        # are charged as 0 — adding inf would poison the running total
        # with nan on the matching decrement.
        w = self.profiled_fn(job)
        job._queued_wcet = w if math.isfinite(w) else 0.0
        self.queued_wcet += job._queued_wcet
        self.queue.push(job)
        tr = self.tracer
        if tr is not None:
            now = self.loop.now
            for f in job.frames:
                tr.emit(T.EDF_ENQUEUE, now, f.request_id, f.index,
                        where=self.tracer_tag, cat=str(job.category),
                        meta={"job_id": job.job_id, "deadline": job.deadline})
        self._schedule_dispatch()

    def _schedule_dispatch(self) -> None:
        """Defer the pick-next-job decision to a PRIO_DISPATCH event at the
        current instant, AFTER all same-instant releases/completions have
        been processed. Starting eagerly here could run a long-deadline job
        released a tick before a same-instant tighter release — an EDF
        inversion the admission imitator never models (it releases
        everything with release <= t before popping)."""
        if self._dispatch_pending:
            return
        self._dispatch_pending = True
        self.loop.schedule(
            self.loop.now,
            self._dispatch,
            priority=getattr(self.loop, "PRIO_DISPATCH", 3),
        )

    def _dispatch(self) -> None:
        self._dispatch_pending = False
        self._retry_scheduled = False
        self._maybe_start()

    # ----- execution -----------------------------------------------------
    def _maybe_start(self) -> None:
        if not self.device.idle:
            return
        if not self.queue:
            # Non-idling + early-flush: pull waiting frames forward.
            if self.request_idle_work is not None and self.request_idle_work():
                # flush_early emitted a job via submit() -> already started.
                return
            return
        t_host = _time.perf_counter()
        job = self._pick_job()
        if job is None:
            return
        self.queued_wcet = max(
            0.0, self.queued_wcet - getattr(job, "_queued_wcet", 0.0)
        )
        if self.chunk_policy is not None:
            job = self._maybe_chunk(job)
        job.start_time = self.loop.now
        job.profiled_wcet = self.profiled_fn(job)
        if isinstance(job, ChunkJob):
            # Inner jobs share the chunk's start instant; their per-step
            # WCETs stay the 1-step table values (per-frame accounting).
            for inner in job.jobs:
                inner.start_time = job.start_time
                inner.profiled_wcet = self.profiled_fn(inner)
        actual = self.exec_time_fn(job)
        jb = self.job_bytes_fn(job) if self.job_bytes_fn is not None else 0.0
        try:
            self.device.submit(job, actual, self._on_complete, job_bytes=jb)
        except TransientSubmitError:
            # The device refused the job without damage (driver hiccup /
            # injected fault): the job is NOT lost and NOT failed — requeue
            # it under its original deadline and retry after a short
            # backoff. EDF order is preserved because the queue re-sorts.
            # A refused chunk is UNFUSED first: its members re-enter the
            # queue individually, so the retry re-evaluates depth against
            # the slack remaining after the backoff.
            self.metrics.submit_retries += 1
            members = job.jobs if isinstance(job, ChunkJob) else [job]
            for m in members:
                m.start_time = None
                m.profiled_wcet = None
                self.queued_wcet += getattr(m, "_queued_wcet", 0.0)
                self.queue.push(m)
            if not self._retry_scheduled:
                self._retry_scheduled = True
                self.loop.schedule(
                    self.loop.now + self.submit_retry_delay,
                    self._dispatch,
                    priority=getattr(self.loop, "PRIO_DISPATCH", 3),
                )
            return
        if self.tracer is not None:
            self._trace_dispatch(job)
        if isinstance(job, ChunkJob) and job.k > 1:
            self.metrics.chunk_submits += 1
            self.metrics.chunked_steps += job.k
        # Host-side stall per dispatch: the microseconds spent picking +
        # launching (async devices return immediately from submit) — the
        # metric the hot-path benchmark tracks against the recorded
        # legacy-blocking numbers.
        self.metrics.record_dispatch_overhead(_time.perf_counter() - t_host)

    # ----- telemetry ------------------------------------------------------
    def _trace_dispatch(self, job) -> None:
        """Stamp the dispatch hop (per member frame: the queue->device
        transition plus the profiled WCET the attribution fold caps the
        device stage at) and the device-submit event (per job)."""
        tr = self.tracer
        now = self.loop.now
        tag = self.tracer_tag
        members = job.jobs if isinstance(job, ChunkJob) else [job]
        prof = job.profiled_wcet
        for m in members:
            cat = str(m.category)
            for f in m.frames:
                tr.emit(T.EDF_DISPATCH, now, f.request_id, f.index,
                        where=tag, cat=cat,
                        meta={"job_id": m.job_id, "profiled": prof})
        tr.emit(T.DEVICE_SUBMIT, now, where=tag,
                meta={"job_id": job.job_id, "batch": job.batch_size,
                      "k": getattr(job, "k", 1), "profiled": prof})

    def _trace_terminal(self, frame, now: float) -> None:
        """Exactly one terminal span per completed frame: ``completed``
        at/before its deadline, ``late`` past it (the deadline-miss
        attribution fires inside the tracer on ``late``)."""
        missed = frame.missed
        self.tracer.emit(
            T.LATE if missed else T.COMPLETED, now,
            frame.request_id, frame.index, where=self.tracer_tag,
            cat=str(frame.category),
            meta={"overdue": frame.overdue} if missed else None)

    def _maybe_chunk(self, head: JobInstance):
        """Fuse the picked job with the next queued same-category jobs
        into a k-step decode chunk, depth chosen from deadline slack.

        Returns the (possibly depth-1) ChunkJob for eligible decode jobs
        — so the dispatch path is uniform and the decision is logged —
        or the plain job when the category has no chunk family. Only
        CONSECUTIVE earliest-deadline queued jobs are taken: the scan
        over the deadline-ordered snapshot stops at the first job of a
        different category, so fusing never leapfrogs a tighter job of
        another stream.
        """
        pol = self.chunk_policy
        if not pol.eligible_fn(head):
            return head
        depths = pol.depths_fn(head)
        if not depths:
            return head
        now = self.loop.now
        run = [head]
        max_depth = max(depths)
        for j in self.queue.snapshot():
            if len(run) >= max_depth:
                break
            if j.category != head.category or not pol.eligible_fn(j):
                break
            run.append(j)
        chosen = 1
        for d in depths:
            if d > len(run):
                break
            w = pol.wcet_fn(head, d)
            if not math.isfinite(w):
                break
            need = w + pol.margin_fn(head)
            # Every member of the candidate chunk must clear it — the
            # head (earliest deadline) usually binds, but a member with
            # a tight deadline released late degrades the depth.
            if all(j.deadline - now >= need - 1e-12 for j in run[:d]):
                chosen = d
        if len(self.chunk_log) == CHUNK_LOG_CAP:
            self.chunk_log_overflow += 1
        self.chunk_log.append((now, chosen, head.job_id))
        self.chunk_depth_counts[chosen] = (
            self.chunk_depth_counts.get(chosen, 0) + 1
        )
        if self.tracer is not None:
            self.tracer.emit(
                T.CHUNK_FUSE, now, where=self.tracer_tag,
                cat=str(head.category),
                meta={"depth": chosen, "head_job_id": head.job_id,
                      "run": len(run)})
        for extra in run[1:chosen]:
            self.queue.remove(extra)
            self.queued_wcet = max(
                0.0, self.queued_wcet - getattr(extra, "_queued_wcet", 0.0)
            )
        return ChunkJob(run[:chosen])

    def _pick_job(self) -> Optional[JobInstance]:
        """EDF pop, with a background-server guard for non-RT jobs.

        A non-RT job may only start if it completes before the earliest
        upcoming real-time window joint; otherwise its non-preemptive
        execution would inject blocking the admission test never modeled
        (paper §3.3 bounds this inversion via a large imposed period — we
        eliminate it entirely). A deferred non-RT job is retried when the
        blocking release has passed.
        """
        head = self.queue.peek()
        if head.category.realtime:
            return self.queue.pop()
        next_rt = (
            self.next_rt_release_fn() if self.next_rt_release_fn is not None else None
        )
        if next_rt is None:
            return self.queue.pop()
        wcet = self.profiled_fn(head)
        if self.loop.now + wcet <= next_rt + 1e-12:
            return self.queue.pop()
        rt_job = self.queue.pop_earliest_realtime()
        if rt_job is not None:
            return rt_job
        # Everything queued is non-RT and unsafe to start: retry at the
        # blocking release (PRIO_DISPATCH orders it after that joint fires).
        if not self._retry_scheduled:
            self._retry_scheduled = True
            self.loop.schedule(
                next_rt,
                self._dispatch,
                priority=getattr(self.loop, "PRIO_DISPATCH", 3),
            )
        return None

    def on_device_idle(self) -> None:
        self._schedule_dispatch()

    def _on_complete(self, job: JobInstance, now: float) -> None:
        if job.completion_time is not None:
            # Duplicated completion signal (a retried ack — see
            # ``faults.DUP_COMPLETE``). The first signal already recorded
            # the job, its frames, the adaptation hooks, and any chained
            # lease release; a second pass would double-count all of
            # them, so the duplicate is counted and dropped here.
            self.metrics.duplicate_completions += 1
            return
        job.completion_time = now
        actual = now - job.start_time
        tr = self.tracer
        if tr is not None:
            tr.emit(T.DEVICE_COMPLETE, now, where=self.tracer_tag,
                    meta={"job_id": job.job_id, "dur": actual,
                          "k": getattr(job, "k", 1),
                          "profiled": job.profiled_wcet})
        if isinstance(job, ChunkJob):
            # Fan the single device completion back out to the chunk's
            # member jobs IN ORDER: each keeps its own frames, deadlines,
            # and adaptation attribution. The per-member actual is the
            # chunk's even per-step share — the adaptation module
            # compares it against the 1-step table WCET, and charging a
            # member the whole chunk time would register a k× phantom
            # overrun on every fused dispatch.
            share = actual / job.k
            for inner in job.jobs:
                inner.completion_time = now
                self.completed_jobs.append(inner)
                rows = (
                    self.executed_rows_fn(inner)
                    if self.executed_rows_fn is not None
                    else bucket(inner.batch_size)
                )
                self.metrics.record_job(inner.batch_size, rows)
                for f in inner.frames:
                    f.completion_time = now
                    self.metrics.record_frame(f)
                    if tr is not None:
                        self._trace_terminal(f, now)
                if self.on_job_complete is not None:
                    self.on_job_complete(inner, share)
            # Overrun/underrun is judged ONCE, chunk actual vs chunk
            # WCET (attributed to the head member below).
        else:
            self.completed_jobs.append(job)
            # Charge the batch-slot rows that actually executed (prefill:
            # the power-of-two bucket; arena decode: max_slots, via the
            # bridge's executed_rows_fn override).
            rows = (
                self.executed_rows_fn(job)
                if self.executed_rows_fn is not None
                else bucket(job.batch_size)
            )
            self.metrics.record_job(job.batch_size, rows)
            for f in job.frames:
                f.completion_time = now
                self.metrics.record_frame(f)
                if tr is not None:
                    self._trace_terminal(f, now)
            if self.on_job_complete is not None:
                self.on_job_complete(job, actual)
        if job.profiled_wcet is not None:
            if actual > job.profiled_wcet + 1e-9:
                self.metrics.overruns += 1
                if self.on_overrun is not None:
                    self.on_overrun(job, actual - job.profiled_wcet)
            elif actual < job.profiled_wcet - 1e-9:
                if self.on_underrun is not None:
                    self.on_underrun(job, job.profiled_wcet - actual)
        # Device calls on_idle -> on_device_idle -> dispatch, via the
        # scheduler wiring; also schedule directly for standalone use.
        if self.device.on_idle is None:
            self._schedule_dispatch()
