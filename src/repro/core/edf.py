"""Non-idling, non-preemptive EDF execution (paper §3.3, §4.3).

The Worker consumes a deadline-ordered priority queue of job instances and
executes them one at a time on a sequential device. Non-idling: whenever
the device goes idle and the queue is non-empty, the earliest-deadline job
starts immediately; if the queue is empty but frames are waiting in the
DisBatcher, the early-flush optimization fires.

The Worker is also the monitoring point (paper §4.3): it records deadline
misses and reports overruns (actual execution time exceeding the profiled
WCET) to the Adaptation Module.
"""
from __future__ import annotations

import heapq
import math
import time as _time
from typing import Callable, List, Optional

from repro.core.bucketing import bucket
from repro.core.faults import TransientSubmitError
from repro.core.request import JobInstance
from repro.core.simulator import Metrics


class DeadlineQueue:
    """Priority queue keyed on absolute deadline (ties: creation order)."""

    def __init__(self):
        self._heap: List[JobInstance] = []

    def push(self, job: JobInstance) -> None:
        heapq.heappush(self._heap, job)

    def pop(self) -> JobInstance:
        return heapq.heappop(self._heap)

    def peek(self) -> JobInstance:
        return self._heap[0]

    def pop_earliest_realtime(self) -> Optional[JobInstance]:
        """Pop the earliest-deadline REAL-TIME job, if any (O(n) scan;
        queues are short). Used when the head is a deferred non-RT job."""
        rt = [j for j in self._heap if j.category.realtime]
        if not rt:
            return None
        target = min(rt)
        self._heap.remove(target)
        heapq.heapify(self._heap)
        return target

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def snapshot(self) -> List[JobInstance]:
        """Jobs currently queued, in deadline order (for admission §4.2)."""
        return sorted(self._heap)


class EDFWorker:
    """Sequential EDF executor + performance monitor.

    Parameters
    ----------
    device:
        ``SequentialDevice`` — executes one job at a time.
    exec_time_fn:
        job -> actual execution seconds. In simulation this samples the
        "real" execution time (possibly above the profiled WCET: an
        overrun); in live serving it returns the profiled WCET, which
        only seeds the async device's ``busy_until`` estimate (the
        device itself reports the real completion instant).
    profiled_fn:
        job -> profiled WCET seconds (the lookup-table value).
    on_overrun:
        callback(job, excess_seconds) — wired to the Adaptation Module.
    on_underrun:
        callback(job, saved_seconds) — repays adaptation penalty.
    """

    def __init__(
        self,
        loop,
        device,
        exec_time_fn: Callable[[JobInstance], float],
        profiled_fn: Callable[[JobInstance], float],
        metrics: Optional[Metrics] = None,
        on_overrun: Optional[Callable[[JobInstance, float], None]] = None,
        on_underrun: Optional[Callable[[JobInstance, float], None]] = None,
        on_job_complete: Optional[Callable[[JobInstance, float], None]] = None,
        request_idle_work: Optional[Callable[[], bool]] = None,
        next_rt_release_fn: Optional[Callable[[], Optional[float]]] = None,
    ):
        self.loop = loop
        self.device = device
        self.queue = DeadlineQueue()
        self.exec_time_fn = exec_time_fn
        self.profiled_fn = profiled_fn
        self.metrics = metrics if metrics is not None else Metrics()
        self.on_overrun = on_overrun
        self.on_underrun = on_underrun
        self.on_job_complete = on_job_complete
        self.request_idle_work = request_idle_work
        self.next_rt_release_fn = next_rt_release_fn
        self.job_bytes_fn: Optional[Callable[[JobInstance], float]] = None
        # job -> batch-slot rows the execution backend actually ran.
        # Default: the power-of-two prefill bucket. The live bridge
        # overrides it for slot-arena decode, which always executes
        # max_slots rows regardless of the job's batch size.
        self.executed_rows_fn: Optional[Callable[[JobInstance], int]] = None
        self.completed_jobs: List[JobInstance] = []
        # Backoff before re-submitting after a transient device error
        # (seconds; virtual under EventLoop, real under WallClock).
        self.submit_retry_delay = 0.005
        self._retry_scheduled = False  # a future-time retry is pending
        self._dispatch_pending = False  # a same-instant dispatch is pending
        # Running WCET total of queued (not yet started) jobs — O(1)
        # backpressure input for the ingest gateway's per-frame shed
        # decision (summing the queue per arriving frame would be
        # O(queue) on the arrival hot path).
        self.queued_wcet = 0.0

    # ----- queue interface (DisBatcher emit target) ---------------------
    def submit(self, job: JobInstance) -> None:
        # Snapshot the charge so the decrement at pop matches even if
        # the table is rescaled (mark_slow) while the job is queued.
        # Non-finite WCETs (a flat entry's inf for an unservable batch)
        # are charged as 0 — adding inf would poison the running total
        # with nan on the matching decrement.
        w = self.profiled_fn(job)
        job._queued_wcet = w if math.isfinite(w) else 0.0
        self.queued_wcet += job._queued_wcet
        self.queue.push(job)
        self._schedule_dispatch()

    def _schedule_dispatch(self) -> None:
        """Defer the pick-next-job decision to a PRIO_DISPATCH event at the
        current instant, AFTER all same-instant releases/completions have
        been processed. Starting eagerly here could run a long-deadline job
        released a tick before a same-instant tighter release — an EDF
        inversion the admission imitator never models (it releases
        everything with release <= t before popping)."""
        if self._dispatch_pending:
            return
        self._dispatch_pending = True
        self.loop.schedule(
            self.loop.now,
            self._dispatch,
            priority=getattr(self.loop, "PRIO_DISPATCH", 3),
        )

    def _dispatch(self) -> None:
        self._dispatch_pending = False
        self._retry_scheduled = False
        self._maybe_start()

    # ----- execution -----------------------------------------------------
    def _maybe_start(self) -> None:
        if not self.device.idle:
            return
        if not self.queue:
            # Non-idling + early-flush: pull waiting frames forward.
            if self.request_idle_work is not None and self.request_idle_work():
                # flush_early emitted a job via submit() -> already started.
                return
            return
        t_host = _time.perf_counter()
        job = self._pick_job()
        if job is None:
            return
        self.queued_wcet = max(
            0.0, self.queued_wcet - getattr(job, "_queued_wcet", 0.0)
        )
        job.start_time = self.loop.now
        job.profiled_wcet = self.profiled_fn(job)
        actual = self.exec_time_fn(job)
        jb = self.job_bytes_fn(job) if self.job_bytes_fn is not None else 0.0
        try:
            self.device.submit(job, actual, self._on_complete, job_bytes=jb)
        except TransientSubmitError:
            # The device refused the job without damage (driver hiccup /
            # injected fault): the job is NOT lost and NOT failed — requeue
            # it under its original deadline and retry after a short
            # backoff. EDF order is preserved because the queue re-sorts.
            self.metrics.submit_retries += 1
            self.queued_wcet += getattr(job, "_queued_wcet", 0.0)
            self.queue.push(job)
            if not self._retry_scheduled:
                self._retry_scheduled = True
                self.loop.schedule(
                    self.loop.now + self.submit_retry_delay,
                    self._dispatch,
                    priority=getattr(self.loop, "PRIO_DISPATCH", 3),
                )
            return
        # Host-side stall per dispatch: the microseconds spent picking +
        # launching (async devices return immediately from submit) — the
        # metric the hot-path benchmark tracks against the recorded
        # legacy-blocking numbers.
        self.metrics.record_dispatch_overhead(_time.perf_counter() - t_host)

    def _pick_job(self) -> Optional[JobInstance]:
        """EDF pop, with a background-server guard for non-RT jobs.

        A non-RT job may only start if it completes before the earliest
        upcoming real-time window joint; otherwise its non-preemptive
        execution would inject blocking the admission test never modeled
        (paper §3.3 bounds this inversion via a large imposed period — we
        eliminate it entirely). A deferred non-RT job is retried when the
        blocking release has passed.
        """
        head = self.queue.peek()
        if head.category.realtime:
            return self.queue.pop()
        next_rt = (
            self.next_rt_release_fn() if self.next_rt_release_fn is not None else None
        )
        if next_rt is None:
            return self.queue.pop()
        wcet = self.profiled_fn(head)
        if self.loop.now + wcet <= next_rt + 1e-12:
            return self.queue.pop()
        rt_job = self.queue.pop_earliest_realtime()
        if rt_job is not None:
            return rt_job
        # Everything queued is non-RT and unsafe to start: retry at the
        # blocking release (PRIO_DISPATCH orders it after that joint fires).
        if not self._retry_scheduled:
            self._retry_scheduled = True
            self.loop.schedule(
                next_rt,
                self._dispatch,
                priority=getattr(self.loop, "PRIO_DISPATCH", 3),
            )
        return None

    def on_device_idle(self) -> None:
        self._schedule_dispatch()

    def _on_complete(self, job: JobInstance, now: float) -> None:
        if job.completion_time is not None:
            # Duplicated completion signal (a retried ack — see
            # ``faults.DUP_COMPLETE``). The first signal already recorded
            # the job, its frames, the adaptation hooks, and any chained
            # lease release; a second pass would double-count all of
            # them, so the duplicate is counted and dropped here.
            self.metrics.duplicate_completions += 1
            return
        job.completion_time = now
        self.completed_jobs.append(job)
        # Charge the batch-slot rows that actually executed (prefill: the
        # power-of-two bucket; arena decode: max_slots, via the bridge's
        # executed_rows_fn override).
        rows = (
            self.executed_rows_fn(job)
            if self.executed_rows_fn is not None
            else bucket(job.batch_size)
        )
        self.metrics.record_job(job.batch_size, rows)
        for f in job.frames:
            f.completion_time = now
            self.metrics.record_frame(f)
        actual = now - job.start_time
        if self.on_job_complete is not None:
            self.on_job_complete(job, actual)
        if job.profiled_wcet is not None:
            if actual > job.profiled_wcet + 1e-9:
                self.metrics.overruns += 1
                if self.on_overrun is not None:
                    self.on_overrun(job, actual - job.profiled_wcet)
            elif actual < job.profiled_wcet - 1e-9:
                if self.on_underrun is not None:
                    self.on_underrun(job, job.profiled_wcet - actual)
        # Device calls on_idle -> on_device_idle -> dispatch, via the
        # scheduler wiring; also schedule directly for standalone use.
        if self.device.on_idle is None:
            self._schedule_dispatch()
