"""Synthetic request traces (paper §6.2).

Periods and relative deadlines are sampled independently from a Gamma
distribution (shape k=2, scale θ=5 — the paper's queueing-theory choice)
and rescaled so the trace mean matches a target (paper Table 2: 50/150/250
ms on the desktop, 300/450/600 ms on the Jetson). Request inter-arrival
times follow a bursty exponential process standing in for the Twitter
trace the paper uses as an arrival-pattern reference. Each request picks a
model and an input shape uniformly from the configured pools, with the
number of distinct categories capped (paper: "we limit the number of
categories of requests").
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.request import Category, Request

GAMMA_K = 2.0
GAMMA_THETA = 5.0


@dataclass
class TraceSpec:
    mean_period: float  # seconds
    mean_deadline: float  # seconds
    n_requests: int = 25  # paper: 20-30 per trace
    frames_per_request: Tuple[int, int] = (30, 120)
    models: Sequence[str] = ("resnet50",)
    shapes: Sequence[Tuple[int, ...]] = ((3, 224, 224),)
    max_categories: int = 4
    mean_interarrival: float = 1.0  # request arrivals (Twitter-like)
    seed: int = 0


def _gamma_scaled(rng: random.Random, mean: float) -> float:
    raw = rng.gammavariate(GAMMA_K, GAMMA_THETA)
    return max(raw * mean / (GAMMA_K * GAMMA_THETA), 1e-4)


def generate_trace(spec: TraceSpec) -> List[Request]:
    rng = random.Random(spec.seed)
    pool = [
        Category(model_id=m, shape_key=s)
        for m in spec.models
        for s in spec.shapes
    ]
    rng.shuffle(pool)
    pool = pool[: spec.max_categories]
    out: List[Request] = []
    t = 0.0
    for _ in range(spec.n_requests):
        t += rng.expovariate(1.0 / spec.mean_interarrival)
        cat = rng.choice(pool)
        out.append(
            Request(
                category=cat,
                period=_gamma_scaled(rng, spec.mean_period),
                relative_deadline=_gamma_scaled(rng, spec.mean_deadline),
                n_frames=rng.randint(*spec.frames_per_request),
                start_time=t,
            )
        )
    return out


# The paper's two hardware settings (Table 2), in seconds.
DESKTOP_TRACES = [0.050, 0.150, 0.250]
JETSON_TRACES = [0.300, 0.450, 0.600]
