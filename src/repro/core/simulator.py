"""Discrete-event simulation engine.

Three roles in the reproduction:

1. Virtual clock for the DeepRT scheduler and every baseline, so the
   paper's trace experiments (Figs 4/5/7/10) run deterministically and
   orders of magnitude faster than wall time.
2. The device models: ``SequentialDevice`` (a TPU core: one program at a
   time — also how DeepRT drives a GPU) and ``ProcessorSharingDevice``
   (CUDA time-sliced context multiplexing, reproducing the paper's Fig 2a
   linear-slowdown observation; used only by the concurrent baselines and
   the §2 characterization benchmark).
3. Wall-clock mode: ``WallClock`` swaps in for real serving; the scheduler
   code is identical.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time as _time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.telemetry import LatencyHistogram


class EventLoop:
    """Heap-based virtual-time event loop.

    Events at the SAME timestamp execute in (priority, insertion) order.
    Priorities make same-instant semantics deterministic and independent
    of insertion order — crucial at window-joint boundaries:

      PRIO_ARRIVAL(0) < PRIO_COMPLETE(1) < PRIO_JOINT(2) < PRIO_DISPATCH(3)

    A frame arriving exactly at a window joint therefore joins the window
    that closes at that instant, and the EDF worker only picks its next
    job (PRIO_DISPATCH) after ALL same-instant releases have been pushed —
    the same conventions the Phase-2 EDF imitator uses (it releases every
    job with release <= t before popping).
    """

    PRIO_ARRIVAL = 0
    PRIO_COMPLETE = 1
    PRIO_JOINT = 2
    PRIO_DISPATCH = 3

    def __init__(self, start: float = 0.0):
        self._now = start
        self._heap: list = []
        self._seq = itertools.count()
        self._cancelled: set = set()

    @property
    def now(self) -> float:
        return self._now

    def schedule(
        self, when: float, fn: Callable[[], None], priority: int = 1
    ) -> int:
        if when < self._now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {when} < {self._now}")
        eid = next(self._seq)
        heapq.heappush(self._heap, (max(when, self._now), priority, eid, fn))
        return eid

    def schedule_in(
        self, delay: float, fn: Callable[[], None], priority: int = 1
    ) -> int:
        return self.schedule(self._now + delay, fn, priority)

    def cancel(self, event_id: int) -> None:
        self._cancelled.add(event_id)

    def run(self, until: Optional[float] = None) -> None:
        while self._heap:
            when, _prio, eid, fn = self._heap[0]
            if until is not None and when > until:
                break
            heapq.heappop(self._heap)
            if eid in self._cancelled:
                self._cancelled.discard(eid)
                continue
            self._now = when
            fn()
        if until is not None and until > self._now:
            self._now = until

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][2] in self._cancelled:
            _, _, eid, _ = heapq.heappop(self._heap)
            self._cancelled.discard(eid)
        return self._heap[0][0] if self._heap else None


class WallClock:
    """Wall-clock stand-in with the same scheduling interface.

    Used by the live serving path (examples/serve_multitenant.py).
    Callbacks execute on the thread that called ``run``; ``run`` sleeps on
    a condition variable until *exactly* the next event time (no coarse
    polling granularity — live window joints fire on time) and wakes
    immediately when another thread posts work via ``post``.

    Cross-thread protocol (used by ``serving.async_device.AsyncDevice``):
    - ``post(fn, priority)``    — thread-safe "schedule at now + wake up";
    - ``hold()`` / ``release()``— keep ``run`` alive while external work
      (an in-flight device execution) will post a future completion even
      though the heap is momentarily empty.
    """

    PRIO_ARRIVAL = 0
    PRIO_COMPLETE = 1
    PRIO_JOINT = 2
    PRIO_DISPATCH = 3

    def __init__(self):
        self._t0 = _time.perf_counter()
        self._heap: list = []
        self._seq = itertools.count()
        self._cancelled: set = set()
        self._cond = threading.Condition()
        self._holds = 0

    @property
    def now(self) -> float:
        return _time.perf_counter() - self._t0

    def schedule(self, when: float, fn: Callable[[], None], priority: int = 1) -> int:
        with self._cond:
            eid = next(self._seq)
            heapq.heappush(self._heap, (when, priority, eid, fn))
            self._cond.notify_all()
            return eid

    def schedule_in(self, delay: float, fn: Callable[[], None], priority: int = 1) -> int:
        return self.schedule(self.now + delay, fn, priority)

    def post(self, fn: Callable[[], None], priority: int = 1) -> int:
        """Thread-safe: enqueue ``fn`` at the current instant and wake the
        loop thread. The completion path of the async device."""
        return self.schedule(self.now, fn, priority)

    def hold(self) -> None:
        with self._cond:
            self._holds += 1

    def release(self) -> None:
        with self._cond:
            if self._holds <= 0:
                raise RuntimeError("WallClock.release() without a matching hold()")
            self._holds -= 1
            self._cond.notify_all()

    def cancel(self, event_id: int) -> None:
        self._cancelled.add(event_id)

    def run(self, until: Optional[float] = None) -> None:
        while True:
            fn = None
            with self._cond:
                while True:
                    if self._heap:
                        when, _prio, eid, _fn = self._heap[0]
                        if until is not None and when > until:
                            return
                        wait = when - self.now
                        if wait <= 0:
                            heapq.heappop(self._heap)
                            if eid in self._cancelled:
                                self._cancelled.discard(eid)
                                continue
                            fn = _fn
                            break
                        # Sleep until exactly the next event (or a post()).
                        self._cond.wait(timeout=wait)
                    elif self._holds > 0:
                        # Heap empty but a device execution is in flight;
                        # its completion will be post()ed from the waiter.
                        if until is not None and self.now > until:
                            return
                        self._cond.wait(timeout=0.05)
                    else:
                        return
            # Execute outside the lock: callbacks may schedule() freely.
            fn()


@dataclass
class _Active:
    job: object
    work: float  # remaining isolated-execution seconds
    on_complete: Callable[[object, float], None]
    job_bytes: float = 0.0


class SequentialDevice:
    """One program at a time — a TPU core, or DeepRT's view of the GPU.

    ``submit`` is only legal when idle; the caller (the EDF worker)
    enforces non-preemptive sequential execution.

    THE DEVICE CONTRACT — shared by this simulated device and the live
    ``repro.serving.async_device.AsyncDevice`` (and anything future PRs
    add: multi-device sharding, cluster slices):

    - ``submit(job, exec_time, on_complete, job_bytes=0.0)``: start one
      job. ``exec_time`` is the caller's best estimate (simulation: the
      sampled "actual"; live: the profiled WCET) — it drives
      ``busy_until`` and, for simulated devices only, the completion
      instant. ``on_complete(job, now)`` fires exactly once, on the loop
      thread, at the job's completion time.
    - ``idle`` / ``busy_until``: scheduling state the EDF worker and the
      admission snapshot read; ``busy_until`` is an estimate for live
      devices (actual completion may land earlier or later).
    - ``on_idle``: zero-arg callback invoked after each completion; the
      scheduler wires it to the EDF worker's dispatch.

    The whole point of the contract is that host-side scheduling overlaps
    device execution identically in simulation and live serving: the
    simulated loop keeps processing events while a job "runs", and the
    async device keeps the wall-clock loop free while XLA executes.
    """

    def __init__(self, loop: EventLoop, on_idle: Optional[Callable[[], None]] = None):
        self.loop = loop
        self.on_idle = on_idle
        self._busy_until: Optional[float] = None
        self._closed = False
        self.busy_time = 0.0  # total seconds spent executing
        self.resident_bytes = 0.0  # live batch buffers (Fig 6 benchmark)
        self.peak_bytes = 0.0

    @property
    def idle(self) -> bool:
        # A closed device (its slice failed) is never idle — see
        # AsyncDevice.idle for the rationale; both contract
        # implementations fail-stop identically.
        return not self._closed and self._busy_until is None

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def busy_until(self) -> Optional[float]:
        return self._busy_until

    def close(self) -> None:
        """Fail-stop (idempotent): refuse new submissions, report
        not-idle forever, swallow the in-flight completion if any. The
        cluster's ``fail_slice`` closes the dead slice's device so its
        remaining frames are lost with the slice in simulation exactly
        as they are live — otherwise the sim slice would keep serving
        the frames its re-admitted tails also serve, double-counting
        them in the aggregate metrics."""
        self._closed = True

    def submit(
        self,
        job: object,
        exec_time: float,
        on_complete: Callable[[object, float], None],
        job_bytes: float = 0.0,
    ) -> None:
        if self._closed:
            raise RuntimeError("SequentialDevice is closed (slice failed)")
        if not self.idle:
            raise RuntimeError("SequentialDevice is busy; EDF worker bug")
        start = self.loop.now
        self._busy_until = start + exec_time
        self.busy_time += exec_time
        self.resident_bytes += job_bytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)

        def _done() -> None:
            self._busy_until = None
            self.resident_bytes -= job_bytes
            if self._closed:
                return  # slice died mid-job: frames lost with the slice
            on_complete(job, self.loop.now)
            if self.on_idle is not None:
                self.on_idle()

        self.loop.schedule(start + exec_time, _done, priority=EventLoop.PRIO_COMPLETE)


class ProcessorSharingDevice:
    """CUDA time-sliced context multiplexing (paper §2.2, Fig 2a).

    k concurrently resident jobs each progress at rate 1/k: a job whose
    isolated execution time is w completes after accumulating w seconds of
    service. This reproduces the paper's measured linear growth of
    execution time with concurrency. Used by the AIMD / BATCH /
    BATCH-Delay baselines, which execute categories concurrently, and by
    the §2 characterization benchmark.
    """

    def __init__(self, loop: EventLoop, interference: float = 1.0):
        # interference > 1 models cross-model slowdown beyond pure
        # time-slicing (paper Table 1 shows >k slowdowns for some pairs).
        self.loop = loop
        self.interference = interference
        self._active: List[_Active] = []
        self._last_update = 0.0
        self._completion_event: Optional[int] = None
        self.busy_time = 0.0
        self.peak_bytes = 0.0

    @property
    def n_active(self) -> int:
        return len(self._active)

    def _rate(self) -> float:
        k = len(self._active)
        if k == 0:
            return 0.0
        if k == 1:
            return 1.0
        return 1.0 / (k * self.interference)

    def _drain(self) -> None:
        now = self.loop.now
        dt = now - self._last_update
        if dt > 0 and self._active:
            r = self._rate()
            for a in self._active:
                a.work -= dt * r
            self.busy_time += dt
        self._last_update = now

    def _reschedule(self) -> None:
        if self._completion_event is not None:
            self.loop.cancel(self._completion_event)
            self._completion_event = None
        if not self._active:
            return
        r = self._rate()
        nxt = min(self._active, key=lambda a: a.work)
        eta = max(nxt.work, 0.0) / r
        self._completion_event = self.loop.schedule_in(eta, self._complete_front)

    def _complete_front(self) -> None:
        self._drain()
        self._completion_event = None
        done = [a for a in self._active if a.work <= 1e-12]
        self._active = [a for a in self._active if a.work > 1e-12]
        for a in done:
            a.on_complete(a.job, self.loop.now)
        self._reschedule()

    def submit(
        self,
        job: object,
        exec_time: float,
        on_complete: Callable[[object, float], None],
        job_bytes: float = 0.0,
    ) -> None:
        self._drain()
        self._active.append(_Active(job, exec_time, on_complete, job_bytes))
        self.peak_bytes = max(
            self.peak_bytes, sum(a.job_bytes for a in self._active)
        )
        self._reschedule()


@dataclass
class Metrics:
    """Per-run metrics shared by DeepRT and all baselines.

    Latency distributions are kept in STREAMING log-bucket histograms
    (``latency_hist``/``e2e_hist`` — O(1) memory under millions of
    frames; exact means, percentiles within one bucket growth factor).
    The raw per-sample lists (``frame_latencies``, ``e2e_latencies``,
    ``overdue_times``, ``dispatch_overheads``, ``batch_sizes``) and the
    per-frame ``frame_records`` dict grow with frames served and are
    only populated while ``record_samples`` is True (the default, for
    tests and short benchmark runs); long-lived servers set it False and
    every aggregate below still reads exactly the same values from the
    histograms and running sums.
    """

    record_samples: bool = True
    completed_frames: int = 0
    missed_frames: int = 0
    overdue_times: List[float] = field(default_factory=list)
    frame_latencies: List[float] = field(default_factory=list)
    job_count: int = 0
    batch_sizes: List[int] = field(default_factory=list)
    # Padding accounting: real frames vs. executed bucket slots per job.
    real_rows: int = 0
    bucket_rows: int = 0
    # Host-side scheduler time per dispatch decision (seconds) — the time
    # the event loop is stalled picking + submitting a job. Async dispatch
    # keeps this at microseconds; the deleted legacy blocking path used to
    # stall here for the whole device execution (the recorded numbers the
    # hot-path benchmark replays as its before-arm).
    dispatch_overheads: List[float] = field(default_factory=list)
    overruns: int = 0
    first_arrival: Optional[float] = None
    last_completion: float = 0.0
    peak_resident_bytes: float = 0.0
    # (request_id, frame_index) -> (arrival, deadline, completion)
    frame_records: Dict = field(default_factory=dict)
    # True end-to-end latency: gateway ingest -> completion. Identical to
    # ``frame_latencies`` (scheduler arrival -> completion) unless the
    # ingest gateway queued or deferred the frame upstream.
    e2e_latencies: List[float] = field(default_factory=list)
    # Load-shedding accounting: every frame the gateway drops is counted
    # here (never silently vanished) — total and per request stream.
    dropped_frames: int = 0
    drops_by_request: Dict[int, int] = field(default_factory=dict)
    # Deadline misses per request stream: lets a cohort (e.g. the
    # transport churn benchmark's live sessions) compute its own
    # effective miss rate without per-frame sample recording.
    missed_by_request: Dict[int, int] = field(default_factory=dict)
    # Frames handed to the scheduler (``DeepRT.ingest_frame``), counted
    # INDEPENDENTLY of completions so the conservation property below is
    # falsifiable — a delivered frame the scheduler loses shows up as
    # completed + dropped < ingested.
    delivered_frames: int = 0
    # Slot-mode decode can consume ONE token per stream per step: when a
    # window batches two frames of the same decode stream, the later
    # token cannot be staged this step and is counted here (the frames
    # still complete — this is a visible degradation signal, the cue to
    # shorten windows or shed harder, never a silent overwrite).
    payload_collisions: int = 0
    # Frames that died with their slice: either in the pipeline (delivered
    # but never completed when the slice was failed — reconciled once by
    # ``fail_slice``) or refused at a closed device (counted delivered AND
    # lost, so ``ingested`` still covers them). Conservation for a drained
    # failure run: ``completed + dropped + lost == ingested``.
    lost_frames: int = 0
    # Submits the EDF worker retried after a transient device error.
    submit_retries: int = 0
    # Completion signals that arrived for an already-completed job
    # (``faults.DUP_COMPLETE``): suppressed by the EDF worker's
    # idempotency guard instead of double-counting frames/leases.
    duplicate_completions: int = 0
    # Multi-step decode chunking (``EDFWorker.chunk_policy``): fused
    # dispatches of depth >= 2, and the total decode steps they carried.
    # ``chunked_steps / chunk_submits`` is the mean depth the slack rule
    # actually achieved — the amortization the benchmark measures.
    chunk_submits: int = 0
    chunked_steps: int = 0
    # Streaming latency distributions (always on; O(1) memory).
    latency_hist: LatencyHistogram = field(default_factory=LatencyHistogram)
    e2e_hist: LatencyHistogram = field(default_factory=LatencyHistogram)
    # Running sums backing the means when sample lists are off.
    dispatch_overhead_sum: float = 0.0
    dispatch_count: int = 0

    def record_frame(self, frame) -> None:
        self.completed_frames += 1
        if self.first_arrival is None or frame.arrival_time < self.first_arrival:
            self.first_arrival = frame.arrival_time
        self.last_completion = max(self.last_completion, frame.completion_time)
        e2e = getattr(frame, "e2e_latency", None)
        e2e = e2e if e2e is not None else frame.latency
        self.latency_hist.record(frame.latency)
        self.e2e_hist.record(e2e)
        if self.record_samples:
            self.frame_latencies.append(frame.latency)
            self.e2e_latencies.append(e2e)
            self.frame_records[(frame.request_id, frame.index)] = (
                frame.arrival_time,
                frame.deadline,
                frame.completion_time,
            )
        if frame.missed:
            self.missed_frames += 1
            self.missed_by_request[frame.request_id] = (
                self.missed_by_request.get(frame.request_id, 0) + 1
            )
            if self.record_samples:
                self.overdue_times.append(frame.overdue)

    def record_ingest(self) -> None:
        """One frame delivered into the scheduler at arrival."""
        self.delivered_frames += 1

    def record_drop(self, request_id: int) -> None:
        """One ingested frame shed by the gateway before scheduling."""
        self.dropped_frames += 1
        self.drops_by_request[request_id] = (
            self.drops_by_request.get(request_id, 0) + 1
        )

    def record_lost(self, n: int = 1) -> None:
        """``n`` delivered frames died with a failed slice."""
        self.lost_frames += n

    def record_job(self, batch_size: int, bucket_size: Optional[int] = None) -> None:
        """``bucket_size`` is the executed batch-slot count; callers whose
        execution model pads (the EDF worker over the bucketing engine)
        pass it explicitly. Default = no padding (baselines on the
        processor-sharing device run true batch sizes)."""
        self.job_count += 1
        if self.record_samples:
            self.batch_sizes.append(batch_size)
        self.real_rows += batch_size
        self.bucket_rows += bucket_size if bucket_size is not None else batch_size

    def record_dispatch_overhead(self, seconds: float) -> None:
        self.dispatch_overhead_sum += seconds
        self.dispatch_count += 1
        if self.record_samples:
            self.dispatch_overheads.append(seconds)

    @property
    def miss_rate(self) -> float:
        if self.completed_frames == 0:
            return 0.0
        return self.missed_frames / self.completed_frames

    @property
    def throughput(self) -> float:
        """Completed frames per second of makespan."""
        if self.completed_frames == 0 or self.first_arrival is None:
            return 0.0
        span = self.last_completion - self.first_arrival
        return self.completed_frames / span if span > 0 else float("inf")

    @property
    def mean_batch(self) -> float:
        # real_rows is exactly sum(batch_sizes): the running-sum form
        # keeps this exact with record_samples=False.
        return self.real_rows / self.job_count if self.job_count else 0.0

    @property
    def padding_waste(self) -> float:
        """Fraction of executed batch-bucket slots carrying no real frame."""
        if self.bucket_rows == 0:
            return 0.0
        return 1.0 - self.real_rows / self.bucket_rows

    @property
    def mean_latency(self) -> float:
        """Mean scheduler-arrival -> completion latency (seconds)."""
        return self.latency_hist.mean

    @property
    def mean_e2e_latency(self) -> float:
        """Mean gateway-ingest -> completion latency (seconds)."""
        return self.e2e_hist.mean

    def latency_percentile(self, q: float) -> float:
        """Streaming scheduler-latency quantile (log-bucket estimate)."""
        return self.latency_hist.percentile(q)

    def e2e_percentile(self, q: float) -> float:
        """Streaming end-to-end-latency quantile (log-bucket estimate)."""
        return self.e2e_hist.percentile(q)

    @property
    def ingested_frames(self) -> int:
        """Everything the gateway accepted bytes for: delivered (counted
        at ``record_ingest``, i.e. scheduler arrival) + shed. The
        conservation check ``completed + dropped == ingested`` is
        FALSIFIABLE for a drained ingest-path run: it fails if the
        scheduler ever loses a delivered frame. Runs that fail slices
        extend it to ``completed + dropped + lost == ingested`` — every
        frame that died with a slice is counted in ``lost_frames``.
        (Baselines that record completions without the ingest path leave
        this at dropped-only.)
        """
        return self.delivered_frames + self.dropped_frames

    @property
    def mean_dispatch_overhead(self) -> float:
        """Mean host-side scheduler stall per job dispatch (seconds)."""
        if self.dispatch_count == 0:
            return 0.0
        return self.dispatch_overhead_sum / self.dispatch_count
