"""Performance Profiler (paper §4.1): WCET lookup tables.

The paper profiles each (model, input shape, batch size) offline on the
physical GPU and stores 99th-percentile execution times. We keep that
interface but provide two backends:

- ``MeasuredProfiler``: times a callable (a jit-compiled JAX step) over
  repeated runs and stores the 99th percentile. This is the paper's method
  verbatim; on this CPU-only container it is used with reduced models, and
  the identical code path would run against a real TPU.

- ``AnalyticProfiler``: derives WCET from the roofline terms of the
  *compiled* program (``cost_analysis`` FLOPs/bytes + collective bytes
  parsed from the HLO), scaled by hardware constants and a calibration
  factor. This extends the table to meshes/shapes that were never measured,
  which the elastic-scaling path needs (a slice failure changes capacity —
  re-admission must not wait for a full re-profile).

Both produce a ``ProfileTable``. Lookups for unprofiled batch sizes are
*conservative*: the batch is first rounded up to its power-of-two bucket —
the batch the serving engine actually executes (``repro.core.bucketing``)
— then to the next profiled size (a larger batch never executes faster per
the paper's Fig 2c), falling back to linear extrapolation from the two
largest profiled points beyond the table. Because the engine, the profiler
grid, and this lookup all round through the one shared ``bucket``, the
WCET charged by admission is the WCET of the program that really runs.
"""
from __future__ import annotations

import bisect
import json
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.bucketing import bucket
from repro.core.request import Category

ShapeKey = Tuple[int, ...]
TableKey = Tuple[str, ShapeKey]


def _percentile(sorted_xs: List[float], q: float) -> float:
    if not sorted_xs:
        raise ValueError("empty sample")
    idx = min(len(sorted_xs) - 1, int(math.ceil(q * len(sorted_xs))) - 1)
    return sorted_xs[max(0, idx)]


@dataclass
class ProfileTable:
    """WCET lookup: (model_id, shape_key) -> {batch_size: seconds}.

    Two entry kinds mirror the engine's two execution regimes:

    - bucketed entries (``record``): per-batch-bucket curves, used by
      prefill, whose cost grows with the padded batch;
    - flat entries (``record_flat``): ONE worst-case time per category,
      used by slot-arena decode — the engine executes the identical
      ``max_slots``-row program for every live batch, so per-step cost is
      independent of batch size and a curve would be fiction. Lookups up
      to ``max_slots`` return the flat value; beyond it they return
      ``inf`` — the engine REJECTS oversized decode dispatches (there is
      no bigger program to lazily compile), so charging infinity makes
      admission's Phase-1 filter and Phase-2 imitator reject any request
      stream that could form such a batch instead of crashing the
      serving loop at dispatch time.
    """

    entries: Dict[TableKey, Dict[int, float]] = field(default_factory=dict)
    # (model_id, shape_key) -> (max_slots, seconds): flat decode entries.
    flat_entries: Dict[TableKey, Tuple[int, float]] = field(default_factory=dict)
    # (model_id, shape_key) -> {chunk depth k: seconds}: the flat WCET
    # FAMILY of a decode category's k-step chunked programs. k=1 mirrors
    # the flat entry; deeper k amortize per-dispatch host overhead, so
    # WCET_k < k * WCET_1 on real hardware — but the family must stay
    # monotone in k (a deeper chunk never finishes before a shallower
    # one), which ``record_flat`` enforces at record time.
    chunk_entries: Dict[TableKey, Dict[int, float]] = field(default_factory=dict)
    # Multiplies every lookup; the cluster layer uses it to model degraded
    # capacity (e.g. a straggling or partially failed slice).
    capacity_scale: float = 1.0

    def record(
        self, model_id: str, shape_key: ShapeKey, batch_size: int, wcet: float
    ) -> None:
        if wcet <= 0:
            raise ValueError(f"wcet must be positive, got {wcet}")
        self.entries.setdefault((model_id, tuple(shape_key)), {})[batch_size] = wcet

    def record_flat(
        self,
        model_id: str,
        shape_key: ShapeKey,
        wcet: float,
        max_slots: int,
        k: int = 1,
    ) -> None:
        """Record a slot-arena decode category: one WCET (measured with
        every arena row active — the worst case) for any batch size.

        ``k`` records the WCET of the k-step CHUNKED program (one scanned
        dispatch executing k decode steps). k=1 is the base flat entry;
        every k also lands in the chunk family, monotone-checked: WCET
        must be non-decreasing in k, and WCET_k <= k * WCET_1 would be
        nice but is NOT required (a cold measurement may exceed it) —
        only ordering violations are rejected, because a non-monotone
        family would let the slack rule pick a deeper chunk believing it
        cheaper than a shallower one.
        """
        if wcet <= 0:
            raise ValueError(f"wcet must be positive, got {wcet}")
        if max_slots <= 0:
            raise ValueError(f"max_slots must be positive, got {max_slots}")
        if k <= 0:
            raise ValueError(f"chunk depth must be positive, got {k}")
        key = (model_id, tuple(shape_key))
        family = self.chunk_entries.setdefault(key, {})
        for k2, w2 in family.items():
            if k2 < k and w2 > wcet + 1e-12:
                raise ValueError(
                    f"non-monotone chunk family for {key}: "
                    f"WCET({k2})={w2} > WCET({k})={wcet}"
                )
            if k2 > k and w2 < wcet - 1e-12:
                raise ValueError(
                    f"non-monotone chunk family for {key}: "
                    f"WCET({k2})={w2} < WCET({k})={wcet}"
                )
        family[k] = wcet
        if k == 1:
            self.flat_entries[key] = (max_slots, wcet)
        elif key not in self.flat_entries:
            raise ValueError(
                f"chunk depth {k} recorded before the k=1 base entry for {key}"
            )

    def has(self, model_id: str, shape_key: ShapeKey) -> bool:
        key = (model_id, tuple(shape_key))
        return key in self.entries or key in self.flat_entries

    def wcet(self, model_id: str, shape_key: ShapeKey, batch_size: int) -> float:
        """Conservative WCET for a batch of ``batch_size`` frames."""
        if batch_size <= 0:
            return 0.0
        key = (model_id, tuple(shape_key))
        if key in self.flat_entries:
            slots, t = self.flat_entries[key]
            if batch_size > slots:
                return math.inf  # unservable: arena has no such program
            return t * self.capacity_scale
        try:
            table = self.entries[key]
        except KeyError:
            raise KeyError(
                f"no profile for model={model_id} shape={shape_key}; "
                f"profiled: {sorted(self.entries) + sorted(self.flat_entries)}"
            ) from None
        if batch_size in table:
            return table[batch_size] * self.capacity_scale
        # Not profiled at the true size: charge the bucket the engine will
        # actually execute (identical rounding to serving/engine.py).
        b = bucket(batch_size)
        if b in table:
            return table[b] * self.capacity_scale
        sizes = sorted(table)
        pos = bisect.bisect_left(sizes, b)
        if pos < len(sizes):
            # Round up to the next profiled batch size (conservative).
            return table[sizes[pos]] * self.capacity_scale
        # Beyond the table: linear extrapolation from the top two points
        # (batching curves are ~affine in batch size at large batch).
        if len(sizes) == 1:
            per = table[sizes[-1]] / sizes[-1]
            return per * b * self.capacity_scale
        b1, b2 = sizes[-2], sizes[-1]
        t1, t2 = table[b1], table[b2]
        slope = max((t2 - t1) / (b2 - b1), 0.0)
        return (t2 + slope * (b - b2)) * self.capacity_scale

    def wcet_for(self, category: Category, batch_size: int) -> float:
        return self.wcet(category.model_id, category.shape_key, batch_size)

    def wcet_optimistic(
        self, model_id: str, shape_key: ShapeKey, batch_size: int
    ) -> float:
        """Piecewise-linear interpolated execution time (NOT rounded up).

        Used only by the Phase-1 utilization filter, which by design must
        *underestimate* load (paper §4.2: Phase 1 may over-admit but must
        not reject feasible requests); the conservative ``wcet`` would
        inflate Ũ at unprofiled batch sizes and cause false rejects.
        Admission safety is unaffected — Phase 2 always runs ``wcet``.
        """
        if batch_size <= 0:
            return 0.0
        key = (model_id, tuple(shape_key))
        if key in self.flat_entries:
            # Flat decode cost: the optimistic estimate IS the flat time
            # (running fewer active rows is not measurably cheaper), and
            # beyond max_slots even Phase 1 must see infinity — "may
            # over-admit" never extends to batches the engine rejects.
            slots, t = self.flat_entries[key]
            if batch_size > slots:
                return math.inf
            return t * self.capacity_scale
        table = self.entries[key]
        if batch_size in table:
            return table[batch_size] * self.capacity_scale
        sizes = sorted(table)
        pos = bisect.bisect_left(sizes, batch_size)
        if pos == 0:
            per = table[sizes[0]] / sizes[0]
            return per * batch_size * self.capacity_scale
        if pos == len(sizes):
            return self.wcet(model_id, shape_key, batch_size)  # extrapolation
        b1, b2 = sizes[pos - 1], sizes[pos]
        t1, t2 = table[b1], table[b2]
        frac = (batch_size - b1) / (b2 - b1)
        return (t1 + frac * (t2 - t1)) * self.capacity_scale

    def max_profiled_batch(self, model_id: str, shape_key: ShapeKey) -> int:
        key = (model_id, tuple(shape_key))
        if key in self.flat_entries:
            return self.flat_entries[key][0]
        return max(self.entries[key])

    # -- chunk families ------------------------------------------------
    def chunk_wcet(self, model_id: str, shape_key: ShapeKey, k: int) -> float:
        """Conservative WCET for a k-step decode chunk.

        Exact hit when k was profiled; an unprofiled k rounds UP to the
        next profiled depth (running a deeper chunk's program for fewer
        steps never happens — the worker rounds depths DOWN to profiled
        members — so this path only covers direct table queries); beyond
        the family it falls back to ``k * WCET_1``, the no-amortization
        upper bound.
        """
        if k <= 0:
            return 0.0
        key = (model_id, tuple(shape_key))
        family = self.chunk_entries.get(key)
        if family:
            if k in family:
                return family[k] * self.capacity_scale
            deeper = [k2 for k2 in family if k2 > k]
            if deeper:
                return family[min(deeper)] * self.capacity_scale
        if key not in self.flat_entries:
            raise KeyError(
                f"no flat/chunk profile for model={model_id} shape={shape_key}"
            )
        return k * self.flat_entries[key][1] * self.capacity_scale

    def chunk_depths_profiled(self, model_id: str, shape_key: ShapeKey) -> List[int]:
        """Profiled chunk depths for a decode category, ascending.

        Depths the engine has a compiled-and-measured program for; the
        EDF worker's slack rule only ever picks from this list."""
        key = (model_id, tuple(shape_key))
        return sorted(self.chunk_entries.get(key, ()))

    def has_chunks(self, model_id: str, shape_key: ShapeKey) -> bool:
        """True when a depth > 1 chunk program was profiled for this key."""
        key = (model_id, tuple(shape_key))
        return any(k > 1 for k in self.chunk_entries.get(key, ()))

    def has_any_chunks(self) -> bool:
        """True when ANY category carries a depth > 1 chunk family —
        the signal DeepRT uses to auto-enable chunked dispatch."""
        return any(
            any(k > 1 for k in family) for family in self.chunk_entries.values()
        )

    def scaled(self, factor: float) -> "ProfileTable":
        """A view of this table with capacity degraded by ``factor`` >= 1."""
        return ProfileTable(
            entries=self.entries,
            flat_entries=self.flat_entries,
            chunk_entries=self.chunk_entries,
            capacity_scale=self.capacity_scale * factor,
        )

    # -- persistence ---------------------------------------------------
    def to_json(self) -> str:
        blob = {
            "capacity_scale": self.capacity_scale,
            "entries": [
                {
                    "model_id": model_id,
                    "shape_key": list(shape_key),
                    "table": {str(b): t for b, t in table.items()},
                }
                for (model_id, shape_key), table in sorted(self.entries.items())
            ],
            "flat_entries": [
                {
                    "model_id": model_id,
                    "shape_key": list(shape_key),
                    "max_slots": slots,
                    "wcet": t,
                }
                for (model_id, shape_key), (slots, t) in sorted(
                    self.flat_entries.items()
                )
            ],
            "chunk_entries": [
                {
                    "model_id": model_id,
                    "shape_key": list(shape_key),
                    "table": {str(k): t for k, t in sorted(family.items())},
                }
                for (model_id, shape_key), family in sorted(
                    self.chunk_entries.items()
                )
            ],
        }
        return json.dumps(blob, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "ProfileTable":
        blob = json.loads(text)
        table = cls(capacity_scale=blob.get("capacity_scale", 1.0))
        for e in blob["entries"]:
            for b, t in e["table"].items():
                table.record(e["model_id"], tuple(e["shape_key"]), int(b), float(t))
        for e in blob.get("flat_entries", []):
            table.record_flat(
                e["model_id"], tuple(e["shape_key"]), float(e["wcet"]),
                int(e["max_slots"]),
            )
        for e in blob.get("chunk_entries", []):
            key = (e["model_id"], tuple(e["shape_key"]))
            slots = table.flat_entries.get(key, (0,))[0]
            for k, t in sorted(e["table"].items(), key=lambda kv: int(kv[0])):
                if int(k) == 1:
                    continue  # already restored via flat_entries
                table.record_flat(
                    e["model_id"], tuple(e["shape_key"]), float(t), slots,
                    k=int(k),
                )
        return table


class MeasuredProfiler:
    """The paper's offline profiler: run each config repeatedly, take p99."""

    def __init__(self, warmup: int = 2, runs: int = 20, quantile: float = 0.99):
        self.warmup = warmup
        self.runs = runs
        self.quantile = quantile

    def profile(
        self,
        table: ProfileTable,
        model_id: str,
        shape_key: ShapeKey,
        batch_sizes: List[int],
        step_fn: Callable[[int], None],
        bucketed: bool = True,
    ) -> None:
        """``step_fn(batch_size)`` must execute one full batched step
        synchronously (for JAX: call ``.block_until_ready()`` inside).

        ``bucketed`` (default): batch sizes are rounded to their engine
        bucket first and each distinct bucket is measured ONCE — the
        engine compiles and pads identically for every true size within a
        bucket, so measuring 3 and 4 separately would time the same XLA
        program twice. The measurement is recorded under the bucket,
        which is exactly the key ``ProfileTable.wcet`` consults.
        """
        targets = sorted({bucket(b) for b in batch_sizes}) if bucketed else batch_sizes
        for b in targets:
            for _ in range(self.warmup):
                step_fn(b)
            samples = []
            for _ in range(self.runs):
                t0 = time.perf_counter()
                step_fn(b)
                samples.append(time.perf_counter() - t0)
            samples.sort()
            table.record(model_id, shape_key, b, _percentile(samples, self.quantile))


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target accelerator (defaults: TPU v5e)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    chips: int = 1

    def step_time(
        self, flops: float, hbm_bytes: float, collective_bytes: float
    ) -> float:
        """Roofline execution-time estimate for one step: the max of the
        three terms (compute, memory, interconnect), each idealized."""
        compute = flops / (self.chips * self.peak_flops)
        memory = hbm_bytes / (self.chips * self.hbm_bw)
        collective = collective_bytes / (self.chips * self.ici_bw)
        return max(compute, memory, collective)


class AnalyticProfiler:
    """WCET from compiled-program roofline terms.

    ``cost_fn(batch_size) -> (flops, hbm_bytes, collective_bytes)`` is
    typically backed by ``repro.roofline.analysis`` over a dry-run lowering.
    ``calibration`` maps idealized roofline time to achievable WCET
    (>= 1; e.g. 1/0.6 if the program historically reaches 60% of roofline).
    """

    def __init__(self, hardware: HardwareSpec, calibration: float = 1.5):
        if calibration < 1.0:
            raise ValueError("calibration must be >= 1 (WCET cannot beat roofline)")
        self.hardware = hardware
        self.calibration = calibration

    def profile(
        self,
        table: ProfileTable,
        model_id: str,
        shape_key: ShapeKey,
        batch_sizes: List[int],
        cost_fn: Callable[[int], Tuple[float, float, float]],
    ) -> None:
        for b in batch_sizes:
            flops, hbm, coll = cost_fn(b)
            t = self.hardware.step_time(flops, hbm, coll) * self.calibration
            table.record(model_id, shape_key, b, t)
