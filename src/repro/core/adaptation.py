"""Adaptation Module (paper §4.4): penalty-driven overrun recovery.

Each category carries a penalty, initialized to 0. When the Worker
observes a job instance exceeding its profiled WCET, the excess is added
to the category's penalty and the DisBatcher is told to emit that
category's future job instances at a *reduced shape* (the paper shrinks
image resolution; our TPU adaptation shrinks the padded shape bucket —
e.g. a prefill bucket of 8192 tokens drops to 4096, which was profiled and
pre-compiled up front, so adaptation never triggers a recompile).

While reduced, every completed job repays the penalty by the time saved
relative to the *original-shape* profile; when the penalty reaches 0 the
original shape is restored.

Where no smaller profiled shape exists (e.g. rwkv6 decode: recurrent state
is shape-free), the penalty is still tracked — it then drains through
natural underruns (actual < profiled) — but no shape change happens. This
is the documented fallback for shape-free categories (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.core.disbatcher import DisBatcher
from repro.core.profiler import ProfileTable
from repro.core.request import Category, JobInstance

ShapeKey = Tuple[int, ...]
_EPS = 1e-9


def default_shrink(shape: ShapeKey) -> Optional[ShapeKey]:
    """Halve the spatial/sequence dims; None when nothing can shrink.

    (C, H, W) image -> (C, H//2, W//2); (S,) LM bucket -> (S//2,).
    """
    if len(shape) == 3:
        c, h, w = shape
        if h >= 2 and w >= 2:
            return (c, h // 2, w // 2)
        return None
    if len(shape) >= 1 and shape[-1] >= 2:
        return shape[:-1] + (shape[-1] // 2,)
    return None


class AdaptationModule:
    def __init__(
        self,
        table: ProfileTable,
        disbatcher: DisBatcher,
        shrink_fn: Callable[[ShapeKey], Optional[ShapeKey]] = default_shrink,
        enabled: bool = True,
    ):
        self.table = table
        self.disbatcher = disbatcher
        self.shrink_fn = shrink_fn
        self.enabled = enabled
        self.penalties: Dict[Category, float] = {}
        self.shape_changes = 0  # telemetry
        self.restores = 0

    def penalty(self, category: Category) -> float:
        return self.penalties.get(category, 0.0)

    def _shrunken(self, category: Category) -> Optional[ShapeKey]:
        """The next profiled shape below the category's current shape."""
        cur = self.disbatcher.shape_override(category) or category.shape_key
        cand = self.shrink_fn(cur)
        while cand is not None:
            if self.table.has(category.model_id, cand):
                return cand
            cand = self.shrink_fn(cand)
        return None

    def on_job_complete(self, job: JobInstance, actual: float) -> None:
        if not self.enabled:
            return
        cat = job.category
        if job.shape_key == cat.shape_key:
            # Running at original shape: only overruns matter here.
            profiled = self.table.wcet(cat.model_id, job.shape_key, job.batch_size)
            excess = actual - profiled
            if excess > _EPS:
                self.penalties[cat] = self.penalties.get(cat, 0.0) + excess
                reduced = self._shrunken(cat)
                if reduced is not None:
                    self.disbatcher.set_shape_override(cat, reduced)
                    self.shape_changes += 1
            return
        # Running reduced: repay penalty by time saved vs the original
        # shape's profile (paper: "subtract the saved execution time").
        profiled_orig = self.table.wcet(cat.model_id, cat.shape_key, job.batch_size)
        saved = profiled_orig - actual
        p = self.penalties.get(cat, 0.0) - saved
        if p <= _EPS:
            self.penalties[cat] = 0.0
            self.disbatcher.set_shape_override(cat, None)
            self.restores += 1
        else:
            self.penalties[cat] = p
