"""Adaptation Module (paper §4.4): penalty-driven overrun recovery.

Each category carries a penalty, initialized to 0. When the Worker
observes a job instance exceeding its profiled WCET, the excess is added
to the category's penalty and the DisBatcher is told to emit that
category's future job instances at a *reduced shape* (the paper shrinks
image resolution; our TPU adaptation shrinks the padded shape bucket —
e.g. a prefill bucket of 8192 tokens drops to 4096, which was profiled and
pre-compiled up front, so adaptation never triggers a recompile).

While reduced, every completed job repays the penalty by the time saved
relative to the *original-shape* profile; when the penalty reaches 0 the
original shape is restored.

Where no smaller profiled shape exists (e.g. rwkv6 decode: recurrent state
is shape-free), the penalty is still tracked — it then drains through
natural underruns (actual < profiled) — but no shape change happens. This
is the documented fallback for shape-free categories (DESIGN.md §4).

Arrival-side coupling (ingest gateway): the same penalty signal also
drives LOAD SHEDDING at the other end of the pipeline. The paper shrinks
resolution once a category overruns; the streaming gateway applies the
analogous degradation to a category's *arrival rate* — while a category
carries penalty, ``shed_scale`` tells the gateway to tighten that
category's queue-delay budget (sheds engage earlier), and every shed
frame is reported back via ``note_shed`` so the module sees both halves
of the degradation it is driving (``sheds`` telemetry mirrors
``shape_changes``).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.core.disbatcher import DisBatcher
from repro.core.profiler import ProfileTable
from repro.core.request import Category, JobInstance

ShapeKey = Tuple[int, ...]
_EPS = 1e-9


def default_shrink(shape: ShapeKey) -> Optional[ShapeKey]:
    """Halve the spatial/sequence dims; None when nothing can shrink.

    (C, H, W) image -> (C, H//2, W//2); (S,) LM bucket -> (S//2,).
    """
    if len(shape) == 3:
        c, h, w = shape
        if h >= 2 and w >= 2:
            return (c, h // 2, w // 2)
        return None
    if len(shape) >= 1 and shape[-1] >= 2:
        return shape[:-1] + (shape[-1] // 2,)
    return None


class AdaptationModule:
    def __init__(
        self,
        table: ProfileTable,
        disbatcher: DisBatcher,
        shrink_fn: Callable[[ShapeKey], Optional[ShapeKey]] = default_shrink,
        enabled: bool = True,
    ):
        self.table = table
        self.disbatcher = disbatcher
        self.shrink_fn = shrink_fn
        self.enabled = enabled
        self.penalties: Dict[Category, float] = {}
        self.shape_changes = 0  # telemetry
        self.restores = 0
        self.sheds: Dict[Category, int] = {}  # gateway-reported drops
        # Device-health coupling (SliceHealthMonitor): True while this
        # scheduler's device is drifting (slice suspect/quarantined).
        self.device_degraded = False

    def penalty(self, category: Category) -> float:
        return self.penalties.get(category, 0.0)

    # ----- arrival-side degradation (ingest gateway) --------------------
    PENALIZED_BUDGET_TIGHTEN = 2.0
    # Same lever, different trigger: the slice health monitor reports
    # sustained WCET drift (suspect state) via ``note_device_health``.
    DEGRADED_BUDGET_TIGHTEN = 2.0

    def shed_scale(self, category: Category) -> float:
        """Queue-budget tightening factor for the gateway's load shedder.

        1.0 while the category is healthy; ``PENALIZED_BUDGET_TIGHTEN``
        while it carries overrun penalty — a penalized category's device
        time is already proving scarcer than profiled, so its arrival
        queue must be held to a stricter bound (shed earlier) until the
        penalty drains. Multiplied by ``DEGRADED_BUDGET_TIGHTEN`` while
        the health monitor holds the device degraded (slice suspect):
        every category on a drifting device sheds earlier, penalty or
        not. Disabled adaptation never tightens.
        """
        if not self.enabled:
            return 1.0
        scale = 1.0
        if self.penalties.get(category, 0.0) > _EPS:
            scale = self.PENALIZED_BUDGET_TIGHTEN
        if self.device_degraded:
            scale *= self.DEGRADED_BUDGET_TIGHTEN
        return scale

    def note_device_health(self, healthy: bool) -> None:
        """SliceHealthMonitor report: this scheduler's device entered
        (``healthy=False``) or left (``healthy=True``) a drifting state.
        While degraded, ``shed_scale`` tightens for every category."""
        self.device_degraded = not healthy

    def note_shed(self, category: Category, n: int = 1) -> None:
        """Gateway report: ``n`` frames of ``category`` were shed."""
        self.sheds[category] = self.sheds.get(category, 0) + n

    def telemetry(self) -> Dict[str, object]:
        """JSON-able adaptation state for the cluster telemetry snapshot:
        live penalty mass per category, shape-change / restore counts,
        gateway-reported sheds, and the device-health coupling."""
        return {
            "enabled": self.enabled,
            "device_degraded": self.device_degraded,
            "shape_changes": self.shape_changes,
            "restores": self.restores,
            "penalties": {str(c): p for c, p in self.penalties.items()},
            "sheds": {str(c): n for c, n in self.sheds.items()},
        }

    def _shrunken(self, category: Category) -> Optional[ShapeKey]:
        """The next profiled shape below the category's current shape.

        The candidate must be profiled in the SAME regime as the
        category (bucketed prefill curve vs flat decode entry): a
        prefill category whose halved seq happens to equal some decode
        category's shape must NOT shrink into it — the WCET there is a
        different program's cost, and the serving bridge would dispatch
        the job as the wrong step kind. Flat (slot-arena decode)
        categories never shape-shrink at all: their state is resident
        in a per-seq arena whose rows the stream LEASED — a shrunk seq
        would be a different arena where the stream holds no row.
        Their penalty drains through natural underruns instead, the
        same documented fallback as shape-free categories.
        """
        key = (category.model_id, tuple(category.shape_key))
        if key in self.table.flat_entries:
            return None
        pool = self.table.entries
        cur = self.disbatcher.shape_override(category) or category.shape_key
        cand = self.shrink_fn(cur)
        while cand is not None:
            if (category.model_id, tuple(cand)) in pool:
                return cand
            cand = self.shrink_fn(cand)
        return None

    def on_job_complete(self, job: JobInstance, actual: float) -> None:
        if not self.enabled:
            return
        cat = job.category
        if job.shape_key == cat.shape_key:
            # Running at original shape: only overruns matter here.
            profiled = self.table.wcet(cat.model_id, job.shape_key, job.batch_size)
            excess = actual - profiled
            if excess > _EPS:
                self.penalties[cat] = self.penalties.get(cat, 0.0) + excess
                reduced = self._shrunken(cat)
                if reduced is not None:
                    self.disbatcher.set_shape_override(cat, reduced)
                    self.shape_changes += 1
            return
        # Running reduced: repay penalty by time saved vs the original
        # shape's profile (paper: "subtract the saved execution time").
        profiled_orig = self.table.wcet(cat.model_id, cat.shape_key, job.batch_size)
        saved = profiled_orig - actual
        p = self.penalties.get(cat, 0.0) - saved
        if p <= _EPS:
            self.penalties[cat] = 0.0
            self.disbatcher.set_shape_override(cat, None)
            self.restores += 1
        else:
            self.penalties[cat] = p
